//! Telemetry subsystem: the observability spine of the cluster and the
//! measured counterpart of the paper's §IV completion-time analysis.
//!
//! Three pillars, all dependency-free:
//!
//! * [`registry`] — static [`Counter`]/[`Gauge`]/[`Histogram`] handles
//!   with atomic hot-path increments and a coherent [`snapshot_into`];
//!   **zero steady-state allocation** (pinned by `tests/telemetry.rs`
//!   and the `telemetry/*` bench group with the PR-8
//!   counting-allocator technique);
//! * [`span`] — [`RoundSpan`] lifecycle recording on both data planes
//!   and in the simulator: per-round critical-path breakdown
//!   (wait-first / collect / decode / apply), per-worker straggler
//!   attribution (who delivered the k-th distinct task), and
//!   wasted-work accounting — all RNG- and θ-inert, pinned bitwise by
//!   `tests/reactor_parity.rs`;
//! * [`export`] — Prometheus text-format encoder, JSONL metrics log,
//!   and the [`MetricsServer`] scrape listener that joins the
//!   reactor's `poll(2)` set as a [`crate::util::poll::PollHook`]
//!   (threads plane: pumped between channel waits) — wired up via
//!   `train --metrics-addr ADDR --metrics-log PATH`.
//!
//! The metric catalog below is the single source of truth: every
//! metric is a `static` in [`metrics`], enumerated by [`catalog`], so
//! the registry needs no runtime registration and a snapshot is one
//! ordered pass.  Names follow Prometheus conventions
//! (`straggler_<subsystem>_<what>_<unit|total>`); EXPERIMENTS.md
//! §Observability documents each series and the scrape workflow.

pub mod clock;
pub mod export;
pub mod flight;
pub mod registry;
pub mod span;

pub use clock::ClockSync;
pub use export::{encode_prometheus_into, MetricsLog, MetricsServer};
pub use flight::{AnomalyDetector, FlightEvent, FlightRecorder};
pub use registry::{Counter, Gauge, HistSnapshot, Histogram, Snapshot};
pub use span::{
    spans_from_trace, PhaseSummary, RoundSpan, SpanRecorder, SpanSummary, WastedWork,
    WorkerAttribution,
};

/// Telemetry wiring of one cluster run — `addr`/`log` both `None`
/// means fully off (the default; the data path is bitwise identical
/// either way).
#[derive(Debug, Clone)]
pub struct MetricsConfig {
    /// `host:port` to serve Prometheus text-format scrapes on
    /// (`127.0.0.1:0` picks a free port, printed at startup).
    pub addr: Option<String>,
    /// Path of a JSONL metrics log appended once per applied round.
    pub log: Option<String>,
    /// Flight-recorder ring depth (events retained for `/debug/flight`).
    pub flight_depth: usize,
    /// Anomaly threshold: a worker whose phase EWMA exceeds
    /// `factor ×` the fleet median fires `straggler_anomaly_total`.
    pub anomaly_factor: f64,
}

impl Default for MetricsConfig {
    fn default() -> Self {
        Self {
            addr: None,
            log: None,
            flight_depth: flight::DEFAULT_FLIGHT_DEPTH,
            anomaly_factor: flight::DEFAULT_ANOMALY_FACTOR,
        }
    }
}

impl MetricsConfig {
    pub fn enabled(&self) -> bool {
        self.addr.is_some() || self.log.is_some()
    }
}

/// The static metric catalog.  Counters end in `_total` (or
/// `_<unit>_total` for monotonic time sums), gauges are instantaneous,
/// histograms export the `summary` quantiles.
pub mod metrics {
    use super::registry::{Counter, Gauge, Histogram};

    // ── master / aggregation ─────────────────────────────────────────
    pub static MASTER_ROUNDS_TOTAL: Counter = Counter::new(
        "straggler_master_rounds_total",
        "Rounds whose aggregate was applied to the model",
    );
    pub static MASTER_FRAMES_TOTAL: Counter = Counter::new(
        "straggler_master_frames_total",
        "Result frames ingested by the master data plane",
    );
    pub static MASTER_FRAMES_MALFORMED_TOTAL: Counter = Counter::new(
        "straggler_master_frames_malformed_total",
        "Result frames rejected as malformed by the aggregator",
    );
    pub static MASTER_FRAMES_POST_COMPLETION_TOTAL: Counter = Counter::new(
        "straggler_master_frames_post_completion_total",
        "Frames that arrived after their round had already completed (wasted work)",
    );
    pub static MASTER_TASKS_DUPLICATE_TOTAL: Counter = Counter::new(
        "straggler_master_tasks_duplicate_total",
        "Tasks dropped as duplicates of already-aggregated work",
    );
    pub static MASTER_TASKS_STRANDED_TOTAL: Counter = Counter::new(
        "straggler_master_tasks_stranded_total",
        "Tasks outside the round plan (stranded ranges)",
    );
    pub static RING_FRAMES_STALE_TOTAL: Counter = Counter::new(
        "straggler_ring_frames_stale_total",
        "Frames rejected by the bounded-staleness ring as older than the apply window",
    );
    pub static RING_FRAMES_FUTURE_TOTAL: Counter = Counter::new(
        "straggler_ring_frames_future_total",
        "Frames tagged with a round not yet issued",
    );
    pub static AGGREGATOR_TASKS_DISTINCT: Gauge = Gauge::new(
        "straggler_aggregator_tasks_distinct",
        "Distinct tasks buffered for the currently collecting round",
    );
    pub static RING_ROUNDS_IN_FLIGHT: Gauge = Gauge::new(
        "straggler_ring_rounds_in_flight",
        "Issued-but-unapplied rounds in the bounded-staleness pipeline",
    );
    pub static MASTER_FRAME_POOL_BUFFERS: Gauge = Gauge::new(
        "straggler_master_frame_pool_buffers",
        "Recycled frame buffers parked in the threads-plane frame pool",
    );
    pub static MASTER_DWELL_US: Histogram = Histogram::new(
        "straggler_master_dwell_us",
        "Socket-readiness to aggregation-loop dwell per frame (µs)",
    );

    // ── round critical path (span phases) ────────────────────────────
    pub static ROUND_COMPLETION_MS: Histogram = Histogram::new(
        "straggler_round_completion_ms",
        "Assign-issued to k-th distinct arrival per round (ms)",
    );
    pub static ROUND_WAIT_FIRST_MS: Histogram = Histogram::new(
        "straggler_round_wait_first_ms",
        "Assign-issued to first Result frame per round (ms)",
    );
    pub static ROUND_COLLECT_MS: Histogram = Histogram::new(
        "straggler_round_collect_ms",
        "First frame to k-th distinct arrival per round (ms)",
    );
    pub static ROUND_DECODE_MS: Histogram = Histogram::new(
        "straggler_round_decode_ms",
        "Master-side decode time per coded round (ms)",
    );
    pub static ROUND_APPLY_MS: Histogram = Histogram::new(
        "straggler_round_apply_ms",
        "k-th distinct arrival to theta applied per round (ms)",
    );

    // ── latency anatomy (protocol v5 phase decomposition) ────────────
    pub static PHASE_COMPUTE_MS: Histogram = Histogram::new(
        "straggler_phase_compute_ms",
        "Worker gradient-compute phase per Result frame (ms, worker clock)",
    );
    pub static PHASE_QUEUE_MS: Histogram = Histogram::new(
        "straggler_phase_queue_ms",
        "Worker-queue phase per frame: flush encode to delivery pickup (ms)",
    );
    pub static PHASE_NETWORK_MS: Histogram = Histogram::new(
        "straggler_phase_network_ms",
        "Network phase per frame: delivery send to master arrival, clock-mapped (ms)",
    );
    pub static PHASE_DWELL_MS: Histogram = Histogram::new(
        "straggler_phase_dwell_ms",
        "Master dwell phase per frame: arrival to aggregation loop (ms)",
    );
    pub static ANOMALY_TOTAL: Counter = Counter::new(
        "straggler_anomaly_total",
        "Phase anomalies flagged: worker phase EWMA exceeded factor x fleet median",
    );
    pub static CLOCK_OFFSET_US: Gauge = Gauge::new(
        "straggler_clock_offset_us",
        "Largest-magnitude estimated worker clock offset vs the master (us)",
    );

    // ── reactor data plane ───────────────────────────────────────────
    pub static REACTOR_PUMP_POLLS_TOTAL: Counter = Counter::new(
        "straggler_reactor_pump_polls_total",
        "poll(2) wakeups of the reactor pump loop",
    );
    pub static REACTOR_PUMP_FRAMES_TOTAL: Counter = Counter::new(
        "straggler_reactor_pump_frames_total",
        "Complete frames yielded by the reactor pump",
    );
    pub static REACTOR_WRITEV_BATCHES_TOTAL: Counter = Counter::new(
        "straggler_reactor_writev_batches_total",
        "Vectored send batches flushed by the reactor",
    );
    pub static REACTOR_WRITEV_FRAMES_TOTAL: Counter = Counter::new(
        "straggler_reactor_writev_frames_total",
        "Send buffers covered by those vectored batches",
    );
    pub static REACTOR_SEND_POOL_BUFFERS: Gauge = Gauge::new(
        "straggler_reactor_send_pool_buffers",
        "Recycled send buffers parked in the reactor send pool",
    );

    // ── worker ───────────────────────────────────────────────────────
    pub static WORKER_FRAMES_SENT_TOTAL: Counter = Counter::new(
        "straggler_worker_frames_sent_total",
        "Result frames encoded and handed to delivery by in-process workers",
    );
    pub static WORKER_COMPUTE_US_TOTAL: Counter = Counter::new(
        "straggler_worker_compute_us_total",
        "Worker gradient-compute time, summed across flushes (µs)",
    );
    pub static WORKER_FLUSH_SEND_US_TOTAL: Counter = Counter::new(
        "straggler_worker_flush_send_us_total",
        "Worker socket write+flush time, summed across deliveries (µs)",
    );

    // ── coded path ───────────────────────────────────────────────────
    pub static DECODE_CACHE_HITS_TOTAL: Counter = Counter::new(
        "straggler_decode_cache_hits_total",
        "Decode-weight cache hits on the coded master path",
    );
    pub static DECODE_CACHE_MISSES_TOTAL: Counter = Counter::new(
        "straggler_decode_cache_misses_total",
        "Decode-weight cache misses (full Lagrange rebuilds)",
    );
    pub static DECODE_CACHE_EVICTIONS_TOTAL: Counter = Counter::new(
        "straggler_decode_cache_evictions_total",
        "Decode-weight cache evictions",
    );

    // ── simulator / adaptive ─────────────────────────────────────────
    pub static SIM_ROUNDS_TOTAL: Counter = Counter::new(
        "straggler_sim_rounds_total",
        "Simulated DGD rounds executed by the policy engine loops",
    );
    pub static SIM_REPLANS_TOTAL: Counter = Counter::new(
        "straggler_sim_replans_total",
        "Rounds whose adaptive policy changed the assignment plan",
    );
    pub static SIM_ROUNDS_PER_SEC: Gauge = Gauge::new(
        "straggler_sim_rounds_per_sec",
        "Simulated rounds per wall-clock second, last completed run",
    );
    pub static SIM_EST_MEAN_MS: Gauge = Gauge::new(
        "straggler_sim_est_mean_ms",
        "Mean simulated round completion of the last run (ms)",
    );
    pub static SIM_REPLAN_US: Histogram = Histogram::new(
        "straggler_sim_replan_us",
        "Wall-clock cost of one policy plan + plan materialization (µs)",
    );

    // ── telemetry self-accounting ────────────────────────────────────
    pub static TELEMETRY_SCRAPES_TOTAL: Counter = Counter::new(
        "straggler_telemetry_scrapes_total",
        "Successful /metrics scrapes served",
    );
    pub static TELEMETRY_SCRAPE_ERRORS_TOTAL: Counter = Counter::new(
        "straggler_telemetry_scrape_errors_total",
        "Scrape requests answered with an error status",
    );
}

/// One catalog entry.
#[derive(Clone, Copy)]
pub enum Metric {
    Counter(&'static Counter),
    Gauge(&'static Gauge),
    Histogram(&'static Histogram),
}

/// Every metric the process exports, in exposition order.
pub fn catalog() -> &'static [Metric] {
    use metrics as m;
    static CATALOG: &[Metric] = &[
        Metric::Counter(&m::MASTER_ROUNDS_TOTAL),
        Metric::Counter(&m::MASTER_FRAMES_TOTAL),
        Metric::Counter(&m::MASTER_FRAMES_MALFORMED_TOTAL),
        Metric::Counter(&m::MASTER_FRAMES_POST_COMPLETION_TOTAL),
        Metric::Counter(&m::MASTER_TASKS_DUPLICATE_TOTAL),
        Metric::Counter(&m::MASTER_TASKS_STRANDED_TOTAL),
        Metric::Counter(&m::RING_FRAMES_STALE_TOTAL),
        Metric::Counter(&m::RING_FRAMES_FUTURE_TOTAL),
        Metric::Counter(&m::REACTOR_PUMP_POLLS_TOTAL),
        Metric::Counter(&m::REACTOR_PUMP_FRAMES_TOTAL),
        Metric::Counter(&m::REACTOR_WRITEV_BATCHES_TOTAL),
        Metric::Counter(&m::REACTOR_WRITEV_FRAMES_TOTAL),
        Metric::Counter(&m::WORKER_FRAMES_SENT_TOTAL),
        Metric::Counter(&m::WORKER_COMPUTE_US_TOTAL),
        Metric::Counter(&m::WORKER_FLUSH_SEND_US_TOTAL),
        Metric::Counter(&m::DECODE_CACHE_HITS_TOTAL),
        Metric::Counter(&m::DECODE_CACHE_MISSES_TOTAL),
        Metric::Counter(&m::DECODE_CACHE_EVICTIONS_TOTAL),
        Metric::Counter(&m::SIM_ROUNDS_TOTAL),
        Metric::Counter(&m::SIM_REPLANS_TOTAL),
        Metric::Counter(&m::ANOMALY_TOTAL),
        Metric::Counter(&m::TELEMETRY_SCRAPES_TOTAL),
        Metric::Counter(&m::TELEMETRY_SCRAPE_ERRORS_TOTAL),
        Metric::Gauge(&m::AGGREGATOR_TASKS_DISTINCT),
        Metric::Gauge(&m::RING_ROUNDS_IN_FLIGHT),
        Metric::Gauge(&m::MASTER_FRAME_POOL_BUFFERS),
        Metric::Gauge(&m::REACTOR_SEND_POOL_BUFFERS),
        Metric::Gauge(&m::SIM_ROUNDS_PER_SEC),
        Metric::Gauge(&m::SIM_EST_MEAN_MS),
        Metric::Gauge(&m::CLOCK_OFFSET_US),
        Metric::Histogram(&m::MASTER_DWELL_US),
        Metric::Histogram(&m::ROUND_COMPLETION_MS),
        Metric::Histogram(&m::ROUND_WAIT_FIRST_MS),
        Metric::Histogram(&m::ROUND_COLLECT_MS),
        Metric::Histogram(&m::ROUND_DECODE_MS),
        Metric::Histogram(&m::ROUND_APPLY_MS),
        Metric::Histogram(&m::PHASE_COMPUTE_MS),
        Metric::Histogram(&m::PHASE_QUEUE_MS),
        Metric::Histogram(&m::PHASE_NETWORK_MS),
        Metric::Histogram(&m::PHASE_DWELL_MS),
        Metric::Histogram(&m::SIM_REPLAN_US),
    ];
    CATALOG
}

/// One coherent pass over the catalog into a reused [`Snapshot`] —
/// allocation-free once the snapshot's vectors (and every histogram's
/// scratch) are warm, because the catalog size is fixed.
pub fn snapshot_into(snap: &mut Snapshot) {
    snap.counters.clear();
    snap.gauges.clear();
    snap.hists.clear();
    for m in catalog() {
        match m {
            Metric::Counter(c) => snap.counters.push((c.name(), c.help(), c.get())),
            Metric::Gauge(g) => snap.gauges.push((g.name(), g.help(), g.get())),
            Metric::Histogram(h) => snap.hists.push((h.name(), h.help(), h.snapshot())),
        }
    }
}

/// Convenience allocating snapshot (tests, one-shot dumps).
pub fn snapshot() -> Snapshot {
    let mut s = Snapshot::default();
    snapshot_into(&mut s);
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_names_are_unique_and_prefixed() {
        let names: Vec<&str> = catalog()
            .iter()
            .map(|m| match m {
                Metric::Counter(c) => c.name(),
                Metric::Gauge(g) => g.name(),
                Metric::Histogram(h) => h.name(),
            })
            .collect();
        for (i, a) in names.iter().enumerate() {
            assert!(a.starts_with("straggler_"), "{a} lacks the namespace prefix");
            assert!(!names[i + 1..].contains(a), "duplicate metric name {a}");
        }
    }

    #[test]
    fn snapshot_covers_the_catalog() {
        let s = snapshot();
        assert_eq!(
            s.counters.len() + s.gauges.len() + s.hists.len(),
            catalog().len()
        );
    }
}
