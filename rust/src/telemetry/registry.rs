//! Dependency-free metrics registry: monotonic [`Counter`]s, [`Gauge`]s
//! and [`Histogram`]s declared as `static` handles, incremented on hot
//! paths with relaxed atomics, and read out through a coherent
//! [`Snapshot`].
//!
//! The hard contract (pinned by `tests/telemetry.rs` and the
//! `telemetry/*` bench group with the PR-8 counting-allocator
//! technique) is **zero steady-state allocation**: once a histogram's
//! lazily-built state exists and its quantile estimator has degraded to
//! the fixed grid, `Counter::inc`, `Gauge::set`, `Histogram::record`,
//! and [`snapshot_into`] + the Prometheus encoder perform no heap
//! allocation at all.  Warm-up (the first `record` on a histogram, the
//! exact-mode sample buffer, the first `snapshot_into` growing the
//! reused vectors) is the only place the allocator is touched.
//!
//! Registry state is **process-global and cumulative** — Prometheus
//! counter semantics.  Everything a single run needs per-run-accurate
//! (round spans, attribution) lives in [`crate::telemetry::SpanRecorder`]
//! instead, which is plain local state.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::{RunningStats, StreamingQuantiles};

/// Monotonic counter.  `inc`/`add` are single relaxed atomic RMWs —
/// safe to call from any thread, free of heap traffic.
pub struct Counter {
    name: &'static str,
    help: &'static str,
    v: AtomicU64,
}

impl Counter {
    pub const fn new(name: &'static str, help: &'static str) -> Self {
        Self {
            name,
            help,
            v: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, delta: u64) {
        self.v.fetch_add(delta, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn help(&self) -> &'static str {
        self.help
    }
}

/// Last-write-wins instantaneous value, stored as `f64::to_bits` in an
/// atomic so `set` is one relaxed store.
pub struct Gauge {
    name: &'static str,
    help: &'static str,
    bits: AtomicU64,
}

impl Gauge {
    pub const fn new(name: &'static str, help: &'static str) -> Self {
        Self {
            name,
            help,
            bits: AtomicU64::new(0), // 0u64 == 0.0f64.to_bits()
        }
    }

    #[inline]
    pub fn set(&self, x: f64) {
        self.bits.store(x.to_bits(), Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn help(&self) -> &'static str {
        self.help
    }
}

/// Heap-side histogram state, built on the first `record` (the one
/// warm-up allocation) and reused forever after: the streaming quantile
/// estimator, the moment accumulator, and the scratch vectors the
/// alloc-free snapshot path needs.
struct HistState {
    q: StreamingQuantiles,
    s: RunningStats,
    out: Vec<f64>,
    scratch: Vec<f64>,
}

impl HistState {
    fn new() -> Self {
        Self {
            q: StreamingQuantiles::new(),
            s: RunningStats::new(),
            out: Vec::new(),
            scratch: Vec::new(),
        }
    }
}

/// Quantile levels every histogram exposes (Prometheus `summary`
/// convention plus the p90 the ingest report already prints).
pub const HIST_QUANTILES: [f64; 3] = [0.5, 0.9, 0.99];

/// Streaming histogram: `record` takes an uncontended mutex and pushes
/// one sample into [`StreamingQuantiles`] + [`RunningStats`].  Exact
/// mode buffers the first samples (growing a Vec — warm-up); past
/// `EXACT_CAP` the estimator degrades to a fixed grid and `record` is
/// allocation-free.
pub struct Histogram {
    name: &'static str,
    help: &'static str,
    state: Mutex<Option<HistState>>,
}

impl Histogram {
    pub const fn new(name: &'static str, help: &'static str) -> Self {
        Self {
            name,
            help,
            state: Mutex::new(None),
        }
    }

    #[inline]
    pub fn record(&self, x: f64) {
        if !x.is_finite() {
            return;
        }
        let mut g = self.state.lock().unwrap_or_else(|e| e.into_inner());
        let st = g.get_or_insert_with(HistState::new);
        st.q.push(x);
        st.s.push(x);
    }

    /// Coherent point-in-time read-out.  Allocation-free once the
    /// state's `out`/`scratch` vectors are warm (first call, or exact
    /// mode's copy-and-sort before grid degrade, grows them).
    pub fn snapshot(&self) -> HistSnapshot {
        let mut g = self.state.lock().unwrap_or_else(|e| e.into_inner());
        let Some(st) = g.as_mut() else {
            return HistSnapshot::default();
        };
        if st.q.count() == 0 {
            return HistSnapshot::default();
        }
        st.q.quantiles_with(&HIST_QUANTILES, &mut st.out, &mut st.scratch);
        HistSnapshot {
            count: st.s.count(),
            mean: st.s.mean(),
            p50: st.out[0],
            p90: st.out[1],
            p99: st.out[2],
            max: st.s.max(),
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn help(&self) -> &'static str {
        self.help
    }
}

/// One histogram's exported summary.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct HistSnapshot {
    pub count: u64,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

/// A coherent one-pass read-out of the whole catalog.  Reuse one
/// `Snapshot` across scrapes: `snapshot_into` clears and refills the
/// vectors in place, so at a fixed catalog size the refill is
/// allocation-free after the first call.
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    pub counters: Vec<(&'static str, &'static str, u64)>,
    pub gauges: Vec<(&'static str, &'static str, f64)>,
    pub hists: Vec<(&'static str, &'static str, HistSnapshot)>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts() {
        static C: Counter = Counter::new("t_total", "test");
        assert_eq!(C.get(), 0);
        C.inc();
        C.add(4);
        assert_eq!(C.get(), 5);
        assert_eq!(C.name(), "t_total");
    }

    #[test]
    fn gauge_stores_last_write() {
        static G: Gauge = Gauge::new("t_g", "test");
        assert_eq!(G.get(), 0.0);
        G.set(2.5);
        G.set(-1.25);
        assert_eq!(G.get(), -1.25);
    }

    #[test]
    fn histogram_snapshot_tracks_samples() {
        static H: Histogram = Histogram::new("t_h", "test");
        assert_eq!(H.snapshot(), HistSnapshot::default());
        for i in 1..=100 {
            H.record(i as f64);
        }
        H.record(f64::NAN); // ignored
        let s = H.snapshot();
        assert_eq!(s.count, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!((s.p50 - 50.0).abs() <= 1.0);
        assert!(s.p99 >= 98.0 && s.p99 <= 100.0);
        assert_eq!(s.max, 100.0);
    }
}
