//! Round critical-path spans: the measured counterpart of the paper's
//! §IV completion-time decomposition.
//!
//! A [`RoundSpan`] marks the lifecycle of one DGD round as the master
//! drives it — assign-issued → first `Result` frame → k-th distinct
//! arrival (round completion) → decode start/end → θ-apply — and the
//! [`SpanRecorder`] folds finished spans into per-phase quantiles,
//! per-worker straggler attribution (who delivered the k-th distinct
//! task, i.e. who sat on the critical path), and wasted-work accounting
//! (post-completion frames, duplicate-dropped and stranded task ranges,
//! stale/future frames rejected by the bounded-staleness
//! [`crate::coordinator::aggregate::AggregatorRing`]).
//!
//! The recorder is **RNG- and θ-inert by construction**: it only ever
//! *reads* timestamps and identities the data plane already produced,
//! consumes no RNG stream, and never touches frame or message order —
//! `tests/reactor_parity.rs` pins this bitwise (telemetry on vs off).
//! Timestamps are µs from any monotonic origin: the live master feeds
//! `now_us()` wall-clock, the simulator feeds simulated-time µs through
//! a [`SpanRecorder::silent`] recorder (local summary only, nothing
//! published to the process-global registry — simulated milliseconds
//! must not pollute the wall-clock histograms a scrape exports).

use anyhow::{ensure, Result};

use super::metrics as tm;
use crate::report::Table;
use crate::trace::TraceStore;
use crate::util::json::Json;
use crate::util::stats::{RunningStats, StreamingQuantiles};

/// Lifecycle marks of one in-flight round, all in µs from a common
/// monotonic origin.  Slots live in the recorder's ring window (depth =
/// staleness bound) until θ-apply finalizes them.
#[derive(Debug, Clone)]
pub struct RoundSpan {
    pub round: usize,
    pub issue_us: u64,
    pub first_frame_us: Option<u64>,
    pub complete_us: Option<u64>,
    /// Worker that delivered the k-th distinct task (the critical-path
    /// delivery); `None` when the plane could not attribute it.
    pub critical_worker: Option<usize>,
    pub decode_start_us: Option<u64>,
    pub decode_end_us: Option<u64>,
    pub frames: u64,
}

/// Redundant/rejected work observed while rounds were in flight — the
/// measurable price of straggler tolerance.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WastedWork {
    /// Result frames that arrived after their round had completed.
    pub post_completion_frames: u64,
    /// Tasks dropped as duplicates of already-aggregated work.
    pub duplicate_tasks: u64,
    /// Tasks outside the round's plan (stranded ranges).
    pub stranded_tasks: u64,
    /// Frames rejected by the ring as older than the apply window.
    pub stale_frames: u64,
    /// Frames tagged with a round not yet issued.
    pub future_frames: u64,
}

impl WastedWork {
    pub fn total_frames(&self) -> u64 {
        self.post_completion_frames + self.stale_frames + self.future_frames
    }
}

/// Per-worker straggler attribution over a run.
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkerAttribution {
    pub worker: usize,
    /// Rounds whose k-th distinct task this worker delivered.
    pub critical_rounds: u64,
    /// Result frames this worker contributed in total.
    pub frames: u64,
    /// Frames carrying a v5 phase decomposition.
    pub phase_frames: u64,
    /// Mean per-frame phase ms — `[compute, queue, network, dwell]`
    /// from the v5 wire timestamps, clock-mapped onto the master
    /// timeline; all zero when no timed frames were seen.
    pub phase_mean_ms: [f64; 4],
}

/// One phase's distribution over the finished rounds, in milliseconds.
#[derive(Debug, Clone, Copy)]
pub struct PhaseSummary {
    pub count: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl Default for PhaseSummary {
    fn default() -> Self {
        Self {
            count: 0,
            mean_ms: f64::NAN,
            p50_ms: f64::NAN,
            p99_ms: f64::NAN,
            max_ms: f64::NAN,
        }
    }
}

/// Streaming accumulator behind one phase row.
#[derive(Debug, Clone, Default)]
struct PhaseAcc {
    s: RunningStats,
    q: StreamingQuantiles,
}

impl PhaseAcc {
    fn push(&mut self, ms: f64) {
        self.s.push(ms);
        self.q.push(ms);
    }

    fn summary(&self) -> PhaseSummary {
        if self.s.count() == 0 {
            return PhaseSummary::default();
        }
        PhaseSummary {
            count: self.s.count(),
            mean_ms: self.s.mean(),
            p50_ms: self.q.quantile(0.5),
            p99_ms: self.q.quantile(0.99),
            max_ms: self.s.max(),
        }
    }
}

/// End-of-run digest of every finished span: the critical-path phase
/// table, the per-worker attribution, and the wasted-work ledger.
/// Rendered through [`crate::report::Table`] for console + `results/`.
#[derive(Debug, Clone, Default)]
pub struct SpanSummary {
    pub rounds: u64,
    /// issue → k-th distinct arrival (the paper's per-round completion
    /// time, measured).
    pub completion: PhaseSummary,
    /// issue → first frame: the fastest worker's compute + comm.
    pub wait_first: PhaseSummary,
    /// first frame → k-th distinct arrival: the straggling-induced
    /// collect window.
    pub collect: PhaseSummary,
    /// master-side decode (coded schemes; 0-count for uncoded).
    pub decode: PhaseSummary,
    /// k-th distinct arrival → θ applied (master tail, decode included).
    pub apply: PhaseSummary,
    pub attribution: Vec<WorkerAttribution>,
    pub wasted: WastedWork,
}

impl SpanSummary {
    /// `phase × {rounds, mean, p50, p99, max}` (milliseconds).
    pub fn phase_table(&self) -> Table {
        let mut t = Table::new(
            "round critical-path phases (ms)",
            &["phase", "rounds", "mean", "p50", "p99", "max"],
        );
        for (name, p) in [
            ("completion", &self.completion),
            ("wait-first", &self.wait_first),
            ("collect", &self.collect),
            ("decode", &self.decode),
            ("apply", &self.apply),
        ] {
            t.push_row(vec![
                name.into(),
                p.count.to_string(),
                Table::fmt(p.mean_ms),
                Table::fmt(p.p50_ms),
                Table::fmt(p.p99_ms),
                Table::fmt(p.max_ms),
            ]);
        }
        t
    }

    /// Who delivered the k-th distinct task, how often, plus each
    /// worker's frame volume — the per-worker signal adaptive
    /// load-allocation policies consume.
    pub fn attribution_table(&self) -> Table {
        let mut t = Table::new(
            "straggler attribution (k-th distinct deliveries)",
            &[
                "worker",
                "critical rounds",
                "critical %",
                "frames",
                "compute ms",
                "queue ms",
                "network ms",
                "dwell ms",
            ],
        );
        let attributed: u64 = self.attribution.iter().map(|a| a.critical_rounds).sum();
        for a in &self.attribution {
            let pct = if attributed == 0 {
                f64::NAN
            } else {
                100.0 * a.critical_rounds as f64 / attributed as f64
            };
            t.push_row(vec![
                a.worker.to_string(),
                a.critical_rounds.to_string(),
                Table::fmt(pct),
                a.frames.to_string(),
                Table::fmt(a.phase_mean_ms[0]),
                Table::fmt(a.phase_mean_ms[1]),
                Table::fmt(a.phase_mean_ms[2]),
                Table::fmt(a.phase_mean_ms[3]),
            ]);
        }
        t
    }

    /// Frames/tasks that bought no progress.
    pub fn wasted_table(&self) -> Table {
        let mut t = Table::new("wasted work", &["kind", "count"]);
        let w = &self.wasted;
        for (kind, v) in [
            ("post-completion frames", w.post_completion_frames),
            ("duplicate tasks", w.duplicate_tasks),
            ("stranded tasks", w.stranded_tasks),
            ("stale frames", w.stale_frames),
            ("future frames", w.future_frames),
        ] {
            t.push_row(vec![kind.into(), v.to_string()]);
        }
        t
    }

    /// Machine-readable form for `train`'s JSON output path and
    /// `trace report --json`.  Zero-count phases carry NaN stats
    /// internally; those emit as `null` so the output stays strictly
    /// valid JSON for downstream parsers.
    pub fn to_json(&self) -> Json {
        let num = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
        let phase = move |p: &PhaseSummary| {
            Json::obj(vec![
                ("rounds", Json::Num(p.count as f64)),
                ("mean_ms", num(p.mean_ms)),
                ("p50_ms", num(p.p50_ms)),
                ("p99_ms", num(p.p99_ms)),
                ("max_ms", num(p.max_ms)),
            ])
        };
        Json::obj(vec![
            ("rounds", Json::Num(self.rounds as f64)),
            ("completion", phase(&self.completion)),
            ("wait_first", phase(&self.wait_first)),
            ("collect", phase(&self.collect)),
            ("decode", phase(&self.decode)),
            ("apply", phase(&self.apply)),
            (
                "attribution",
                Json::Arr(
                    self.attribution
                        .iter()
                        .map(|a| {
                            Json::obj(vec![
                                ("worker", Json::Num(a.worker as f64)),
                                ("critical_rounds", Json::Num(a.critical_rounds as f64)),
                                ("frames", Json::Num(a.frames as f64)),
                                ("phase_frames", Json::Num(a.phase_frames as f64)),
                                ("compute_ms", Json::Num(a.phase_mean_ms[0])),
                                ("queue_ms", Json::Num(a.phase_mean_ms[1])),
                                ("network_ms", Json::Num(a.phase_mean_ms[2])),
                                ("dwell_ms", Json::Num(a.phase_mean_ms[3])),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "wasted",
                Json::obj(vec![
                    (
                        "post_completion_frames",
                        Json::Num(self.wasted.post_completion_frames as f64),
                    ),
                    ("duplicate_tasks", Json::Num(self.wasted.duplicate_tasks as f64)),
                    ("stranded_tasks", Json::Num(self.wasted.stranded_tasks as f64)),
                    ("stale_frames", Json::Num(self.wasted.stale_frames as f64)),
                    ("future_frames", Json::Num(self.wasted.future_frames as f64)),
                ]),
            ),
        ])
    }
}

/// Records every round's lifecycle and folds finished spans into the
/// run summary.  The window ring holds up to the staleness bound of
/// concurrently in-flight rounds (1 on the synchronous path).
#[derive(Debug, Clone)]
pub struct SpanRecorder {
    window: Vec<Option<RoundSpan>>,
    /// Publish finished spans into the process-global registry
    /// histograms (live master: yes; simulator: no).
    publish: bool,
    rounds: u64,
    completion: PhaseAcc,
    wait_first: PhaseAcc,
    collect: PhaseAcc,
    decode: PhaseAcc,
    apply: PhaseAcc,
    critical_rounds: Vec<u64>,
    frames_by_worker: Vec<u64>,
    /// Per-worker running stats of the four v5 phases
    /// (`[compute, queue, network, dwell]`, ms).
    worker_phase: Vec<[RunningStats; 4]>,
    wasted: WastedWork,
}

impl SpanRecorder {
    /// Live-plane recorder: finished spans also feed the registry's
    /// `straggler_round_*` histograms.
    pub fn new(n_workers: usize, window: usize) -> Self {
        Self::build(n_workers, window, true)
    }

    /// Summary-only recorder (simulator): identical bookkeeping, no
    /// process-global publication.
    pub fn silent(n_workers: usize, window: usize) -> Self {
        Self::build(n_workers, window, false)
    }

    fn build(n_workers: usize, window: usize, publish: bool) -> Self {
        let cap = window.max(1);
        Self {
            window: vec![None; cap],
            publish,
            rounds: 0,
            completion: PhaseAcc::default(),
            wait_first: PhaseAcc::default(),
            collect: PhaseAcc::default(),
            decode: PhaseAcc::default(),
            apply: PhaseAcc::default(),
            critical_rounds: vec![0; n_workers],
            frames_by_worker: vec![0; n_workers],
            worker_phase: vec![Default::default(); n_workers],
            wasted: WastedWork::default(),
        }
    }

    fn slot(&mut self, round: usize) -> Option<&mut RoundSpan> {
        let cap = self.window.len();
        self.window[round % cap]
            .as_mut()
            .filter(|sp| sp.round == round)
    }

    /// The round's Assigns went out.
    pub fn begin(&mut self, round: usize, t_us: u64) {
        let cap = self.window.len();
        self.window[round % cap] = Some(RoundSpan {
            round,
            issue_us: t_us,
            first_frame_us: None,
            complete_us: None,
            critical_worker: None,
            decode_start_us: None,
            decode_end_us: None,
            frames: 0,
        });
    }

    /// A Result frame for `round` was ingested.
    pub fn frame(&mut self, round: usize, worker: usize, t_us: u64) {
        if worker < self.frames_by_worker.len() {
            self.frames_by_worker[worker] += 1;
        }
        if let Some(sp) = self.slot(round) {
            sp.frames += 1;
            sp.first_frame_us.get_or_insert(t_us);
        }
    }

    /// One frame's v5 latency decomposition (ms, already clock-mapped
    /// onto the master timeline by `telemetry/clock.rs`): compute →
    /// worker-queue → network → master-dwell.  Feeds the per-worker
    /// attribution means and, on the live plane, the
    /// `straggler_phase_*` registry histograms.
    pub fn phases(
        &mut self,
        worker: usize,
        comp_ms: f64,
        queue_ms: f64,
        net_ms: f64,
        dwell_ms: f64,
    ) {
        if worker < self.worker_phase.len() {
            let acc = &mut self.worker_phase[worker];
            acc[0].push(comp_ms);
            acc[1].push(queue_ms);
            acc[2].push(net_ms);
            acc[3].push(dwell_ms);
        }
        if self.publish {
            tm::PHASE_COMPUTE_MS.record(comp_ms);
            tm::PHASE_QUEUE_MS.record(queue_ms);
            tm::PHASE_NETWORK_MS.record(net_ms);
            tm::PHASE_DWELL_MS.record(dwell_ms);
        }
    }

    /// The k-th distinct task landed — the round is complete; `worker`
    /// delivered it (the critical-path delivery).  Only the first call
    /// per round sticks.
    pub fn complete(&mut self, round: usize, worker: Option<usize>, t_us: u64) {
        if let Some(sp) = self.slot(round) {
            if sp.complete_us.is_none() {
                sp.complete_us = Some(t_us);
                sp.critical_worker = worker;
            }
        }
    }

    pub fn decode_start(&mut self, round: usize, t_us: u64) {
        if let Some(sp) = self.slot(round) {
            sp.decode_start_us.get_or_insert(t_us);
        }
    }

    pub fn decode_end(&mut self, round: usize, t_us: u64) {
        if let Some(sp) = self.slot(round) {
            sp.decode_end_us = Some(t_us);
        }
    }

    /// θ was updated with the round's aggregate — the span is finished;
    /// fold it into the run accumulators (and the registry when
    /// publishing).
    pub fn apply(&mut self, round: usize, t_us: u64) {
        let cap = self.window.len();
        if !matches!(&self.window[round % cap], Some(sp) if sp.round == round) {
            return;
        }
        let sp = self.window[round % cap].take().expect("matched above");
        let ms = |a: u64, b: u64| (b.saturating_sub(a)) as f64 / 1e3;
        self.rounds += 1;
        let complete = sp.complete_us;
        let completion_ms = ms(sp.issue_us, complete.unwrap_or(t_us));
        self.completion.push(completion_ms);
        if let Some(first) = sp.first_frame_us {
            self.wait_first.push(ms(sp.issue_us, first));
            if let Some(c) = complete {
                self.collect.push(ms(first, c));
            }
        }
        let decode_ms = match (sp.decode_start_us, sp.decode_end_us) {
            (Some(a), Some(b)) => {
                let d = ms(a, b);
                self.decode.push(d);
                d
            }
            _ => 0.0,
        };
        let apply_ms = ms(complete.unwrap_or(sp.issue_us), t_us);
        self.apply.push(apply_ms);
        if let Some(w) = sp.critical_worker {
            if w < self.critical_rounds.len() {
                self.critical_rounds[w] += 1;
            }
        }
        if self.publish {
            tm::ROUND_COMPLETION_MS.record(completion_ms);
            if let Some(first) = sp.first_frame_us {
                tm::ROUND_WAIT_FIRST_MS.record(ms(sp.issue_us, first));
                if let Some(c) = complete {
                    tm::ROUND_COLLECT_MS.record(ms(first, c));
                }
            }
            if sp.decode_start_us.is_some() {
                tm::ROUND_DECODE_MS.record(decode_ms);
            }
            tm::ROUND_APPLY_MS.record(apply_ms);
            tm::MASTER_ROUNDS_TOTAL.inc();
        }
    }

    pub fn wasted_post_completion(&mut self) {
        self.wasted.post_completion_frames += 1;
        if self.publish {
            tm::MASTER_FRAMES_POST_COMPLETION_TOTAL.inc();
        }
    }

    pub fn wasted_duplicate(&mut self, tasks: u64) {
        self.wasted.duplicate_tasks += tasks;
        if self.publish {
            tm::MASTER_TASKS_DUPLICATE_TOTAL.add(tasks);
        }
    }

    pub fn wasted_stranded(&mut self, tasks: u64) {
        self.wasted.stranded_tasks += tasks;
        if self.publish {
            tm::MASTER_TASKS_STRANDED_TOTAL.add(tasks);
        }
    }

    pub fn wasted_stale(&mut self) {
        self.wasted.stale_frames += 1;
        if self.publish {
            tm::RING_FRAMES_STALE_TOTAL.inc();
        }
    }

    pub fn wasted_future(&mut self) {
        self.wasted.future_frames += 1;
        if self.publish {
            tm::RING_FRAMES_FUTURE_TOTAL.inc();
        }
    }

    pub fn summary(&self) -> SpanSummary {
        SpanSummary {
            rounds: self.rounds,
            completion: self.completion.summary(),
            wait_first: self.wait_first.summary(),
            collect: self.collect.summary(),
            decode: self.decode.summary(),
            apply: self.apply.summary(),
            attribution: self
                .critical_rounds
                .iter()
                .zip(&self.frames_by_worker)
                .enumerate()
                .map(|(w, (&c, &f))| {
                    let ph = &self.worker_phase[w];
                    WorkerAttribution {
                        worker: w,
                        critical_rounds: c,
                        frames: f,
                        phase_frames: ph[0].count(),
                        phase_mean_ms: std::array::from_fn(|i| {
                            if ph[i].count() == 0 {
                                0.0
                            } else {
                                ph[i].mean()
                            }
                        }),
                    }
                })
                .collect(),
            wasted: self.wasted,
        }
    }
}

/// Derive the same critical-path/attribution summary **offline** from a
/// recorded trace.  [`crate::trace::TraceEvent`]s carry per-flush
/// compute and comm *durations* (no absolute clocks), so arrivals are
/// reconstructed per `(round, worker)` exactly as the delay model does:
/// a worker computes its flushes sequentially (cumulative `compute_s`)
/// and each flush's `comm_s` rides on top of the compute finish time.
/// Walking all reconstructed arrivals in time order, the event that
/// pushes the round's delivered-task count to `k_tasks` is the
/// completion — its worker is the critical-path delivery; later
/// arrivals in the round are post-completion waste.  Decode/apply
/// phases have no offline counterpart and stay empty.
pub fn spans_from_trace(store: &TraceStore, k_tasks: usize) -> Result<SpanSummary> {
    ensure!(!store.is_empty(), "trace has no events to analyze");
    ensure!(k_tasks > 0, "completion threshold k must be positive");
    let n = store.n_workers();
    let rounds = store.rounds();
    let mut rec = SpanRecorder::silent(n, 1);
    // (arrival_us, worker, tasks), reused per round
    let mut arrivals: Vec<(u64, usize, u64)> = Vec::new();
    let mut cum_compute = vec![0.0f64; n];
    for round in 0..rounds {
        arrivals.clear();
        cum_compute.iter_mut().for_each(|c| *c = 0.0);
        for ev in store.events().iter().filter(|e| e.round as usize == round) {
            let w = ev.worker as usize;
            cum_compute[w] += ev.compute_s;
            let at_us = ((cum_compute[w] + ev.comm_s) * 1e6).round() as u64;
            arrivals.push((at_us, w, ev.tasks as u64));
        }
        if arrivals.is_empty() {
            continue;
        }
        arrivals.sort_by_key(|&(at, w, _)| (at, w));
        rec.begin(round, 0);
        let mut delivered = 0u64;
        let mut done = false;
        let mut complete_at = 0u64;
        for &(at, w, tasks) in &arrivals {
            if done {
                rec.wasted_post_completion();
                continue;
            }
            rec.frame(round, w, at);
            delivered += tasks;
            if delivered >= k_tasks as u64 {
                rec.complete(round, Some(w), at);
                complete_at = at;
                done = true;
            }
        }
        // the trace records only deliveries the master actually saw, so
        // a round that never crosses k (censored tail) still closes at
        // its last arrival, unattributed
        if !done {
            complete_at = arrivals.last().map(|&(at, _, _)| at).unwrap_or(0);
            rec.complete(round, None, complete_at);
        }
        rec.apply(round, complete_at);
    }
    Ok(rec.summary())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_phases_decompose_the_round() {
        let mut rec = SpanRecorder::silent(3, 1);
        rec.begin(0, 1_000);
        rec.frame(0, 1, 3_000); // wait-first = 2 ms
        rec.frame(0, 0, 5_000);
        rec.complete(0, Some(0), 5_000); // completion = 4 ms, collect = 2 ms
        rec.decode_start(0, 5_200);
        rec.decode_end(0, 5_700); // decode = 0.5 ms
        rec.apply(0, 6_000); // apply tail = 1 ms
        let s = rec.summary();
        assert_eq!(s.rounds, 1);
        assert!((s.completion.mean_ms - 4.0).abs() < 1e-9);
        assert!((s.wait_first.mean_ms - 2.0).abs() < 1e-9);
        assert!((s.collect.mean_ms - 2.0).abs() < 1e-9);
        assert!((s.decode.mean_ms - 0.5).abs() < 1e-9);
        assert!((s.apply.mean_ms - 1.0).abs() < 1e-9);
        assert_eq!(s.attribution[0].critical_rounds, 1);
        assert_eq!(s.attribution[1].critical_rounds, 0);
        assert_eq!(s.attribution[1].frames, 1);
    }

    #[test]
    fn phase_means_attribute_per_worker() {
        let mut rec = SpanRecorder::silent(2, 1);
        rec.phases(0, 2.0, 0.1, 0.5, 0.05);
        rec.phases(0, 4.0, 0.3, 1.5, 0.15);
        rec.phases(1, 1.0, 0.2, 8.0, 0.1); // the slow-wire worker
        let s = rec.summary();
        assert_eq!(s.attribution[0].phase_frames, 2);
        assert!((s.attribution[0].phase_mean_ms[0] - 3.0).abs() < 1e-9);
        assert!((s.attribution[0].phase_mean_ms[1] - 0.2).abs() < 1e-9);
        assert!((s.attribution[0].phase_mean_ms[2] - 1.0).abs() < 1e-9);
        assert!((s.attribution[0].phase_mean_ms[3] - 0.1).abs() < 1e-9);
        assert!((s.attribution[1].phase_mean_ms[2] - 8.0).abs() < 1e-9);
        // out-of-range workers are ignored, not a panic
        rec.phases(9, 1.0, 1.0, 1.0, 1.0);
        // JSON carries the phase columns
        let j = rec.summary().to_json().to_string_compact();
        assert!(j.contains("\"network_ms\":8") && j.contains("\"phase_frames\":2"));
    }

    #[test]
    fn window_ring_isolates_concurrent_rounds() {
        let mut rec = SpanRecorder::silent(2, 2);
        rec.begin(0, 0);
        rec.begin(1, 100);
        rec.frame(1, 0, 300);
        rec.frame(0, 1, 400);
        rec.complete(0, Some(1), 400);
        rec.apply(0, 500);
        rec.complete(1, Some(0), 700);
        rec.apply(1, 800);
        let s = rec.summary();
        assert_eq!(s.rounds, 2);
        // round 0 completed at 400 (0.4 ms), round 1 at 700−100 = 0.6 ms
        assert!((s.completion.max_ms - 0.6).abs() < 1e-9);
        assert_eq!(s.attribution[0].critical_rounds, 1);
        assert_eq!(s.attribution[1].critical_rounds, 1);
    }

    #[test]
    fn events_for_unknown_rounds_are_ignored() {
        let mut rec = SpanRecorder::silent(1, 1);
        rec.begin(4, 10);
        rec.frame(3, 0, 20); // slot now owned by round 4 — no cross-talk
        rec.complete(3, Some(0), 30);
        rec.apply(3, 40);
        let s = rec.summary();
        assert_eq!(s.rounds, 0);
        assert_eq!(s.attribution[0].frames, 1); // volume still attributed
    }

    #[test]
    fn wasted_work_ledger_adds_up() {
        let mut rec = SpanRecorder::silent(1, 1);
        rec.wasted_post_completion();
        rec.wasted_duplicate(3);
        rec.wasted_stranded(2);
        rec.wasted_stale();
        rec.wasted_future();
        let w = rec.summary().wasted;
        assert_eq!(w.post_completion_frames, 1);
        assert_eq!(w.duplicate_tasks, 3);
        assert_eq!(w.stranded_tasks, 2);
        assert_eq!(w.stale_frames, 1);
        assert_eq!(w.future_frames, 1);
        assert_eq!(w.total_frames(), 3);
    }

    #[test]
    fn trace_reconstruction_attributes_the_kth_task() {
        use crate::trace::TraceEvent;
        let ev = |worker: u32, slot: u32, compute_s: f64, comm_s: f64| TraceEvent {
            worker,
            round: 0,
            slot,
            tasks: 1,
            compute_s,
            queue_s: 0.0,
            comm_s,
            bytes: 64,
            scheme: "CS".into(),
            replanned: false,
            version: 0,
        };
        // worker 0 lands at 1.1 s and 2.1 s; worker 1 (the straggler)
        // lands at 3.5 s — with k = 3 it delivers the k-th task
        let store = TraceStore::new(vec![
            ev(0, 0, 1.0, 0.1),
            ev(0, 1, 1.0, 0.1),
            ev(1, 0, 3.0, 0.5),
        ])
        .unwrap()
        .with_fleet(2);
        let s = spans_from_trace(&store, 3).unwrap();
        assert_eq!(s.rounds, 1);
        assert_eq!(s.attribution[1].critical_rounds, 1);
        assert_eq!(s.attribution[0].critical_rounds, 0);
        assert!((s.completion.mean_ms - 3_500.0).abs() < 1.0);
        assert!((s.wait_first.mean_ms - 1_100.0).abs() < 1.0);
        // k = 2 instead: worker 0's second flush completes the round
        // and the straggler's delivery becomes post-completion waste
        let s2 = spans_from_trace(&store, 2).unwrap();
        assert_eq!(s2.attribution[0].critical_rounds, 1);
        assert_eq!(s2.wasted.post_completion_frames, 1);
        assert!((s2.completion.mean_ms - 2_100.0).abs() < 1.0);
    }
}
