//! Per-worker delay-model fitting from a recorded [`TraceStore`] — the
//! "fit" leg of the record → fit → replay loop.
//!
//! Two parametric families are fitted per worker and per channel
//! (computation, communication), both against the per-task /
//! per-message millisecond samples the store extracts:
//!
//! * **shifted exponential** (the coded-computation workhorse,
//!   `T = c + Exp(λ)`) by maximum likelihood: `ĉ = min(x)`,
//!   `λ̂ = 1 / (mean(x) − min(x))` — the exact MLE, whose shift is
//!   biased high by `O(1/(λn))` (the minimum of `n` exponentials);
//! * **truncated Gaussian** (the paper's eq. 66 model) by the same
//!   moment fit the Fig. 3 overlay uses
//!   ([`crate::metrics::fit_truncated_gaussian`]): `μ̂ = mean`,
//!   `σ̂ = sample std`, support at the observed extremes.  Under tight
//!   truncation the sample std *understates* the latent `σ` (variance
//!   of a ±1σ-truncated normal is `0.29σ²`), so `σ̂` is the dispersion
//!   of the truncated law, not the latent parameter — which is exactly
//!   what replay needs.
//!
//! Each fit carries a **Kolmogorov–Smirnov distance** against the
//! empirical CDF (`D = sup_t |F̂(t) − F_fit(t)|`, evaluated at the
//! sample points where the sup is attained), so `straggler trace fit`
//! can report which family describes each worker and how well; the
//! family with the smaller KS is the per-channel [`ChannelFit::best`].
//!
//! [`fit_traces`] additionally groups the fleet into **fast/slow
//! tiers** by deterministic 1-D 2-means over the per-worker mean
//! computation delay — the heterogeneity summary that picks GCH-style
//! layouts and seeds the `load`/`load-rate` policies with a prior.
//!
//! A third estimate captures **within-worker round-to-round
//! correlation** (the paper's §II joint CDF `F_{i,[n]}` freedom, which
//! the marginal fits above are blind to): per worker, the between-round
//! variance of the round-mean task delay decomposes into a genuine
//! common-factor part plus sampling noise of the round mean,
//! `Var_t(m_t) ≈ μ² (e^{σ²} − 1) + E_t[v̂_t / c_t]`.  Subtracting the
//! noise term and inverting the mean-1 log-normal variance map gives
//! the per-worker log-std `σ̂_w` ([`FleetFit::sigma`]); wrapping the
//! truncated-Gaussian fleet model in
//! [`crate::delay::WorkerCorrelated`] at the fleet-mean σ̂ is
//! [`FleetFit::correlated_model`] — the `trace replay --replay corr`
//! twin, which reproduces bursty "machine is busy this round" delays
//! that the independent replays smooth away.

use anyhow::{bail, Result};

use crate::delay::exponential::ShiftedExp;
use crate::delay::{TruncatedGaussian, TruncatedGaussianModel, WorkerCorrelated};
use crate::metrics::fit_truncated_gaussian;

use super::record::TraceStore;

/// Kolmogorov–Smirnov distance between the empirical CDF of `samples`
/// and a fitted CDF.  `samples` need not be sorted.
pub fn ks_distance(samples: &[f64], cdf: impl Fn(f64) -> f64) -> f64 {
    assert!(!samples.is_empty(), "KS distance of an empty sample");
    let mut sorted = samples.to_vec();
    sorted.sort_unstable_by(f64::total_cmp);
    let n = sorted.len() as f64;
    let mut d = 0.0f64;
    for (i, &x) in sorted.iter().enumerate() {
        let f = cdf(x);
        // the empirical CDF steps from i/n to (i+1)/n at x: the sup is
        // attained just below or at each sample point
        d = d.max((f - i as f64 / n).abs());
        d = d.max(((i + 1) as f64 / n - f).abs());
    }
    d
}

/// A fitted shifted exponential plus its goodness of fit.
#[derive(Debug, Clone)]
pub struct ShiftedExpFit {
    pub dist: ShiftedExp,
    /// KS distance against the empirical CDF.
    pub ks: f64,
}

/// MLE fit of `shift + Exp(rate)` to millisecond samples.
pub fn fit_shifted_exp(samples: &[f64]) -> ShiftedExpFit {
    assert!(samples.len() >= 2, "need ≥ 2 samples to fit");
    let min = samples.iter().copied().fold(f64::INFINITY, f64::min);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    // a degenerate (constant) stream has mean == min; clamp the rate so
    // the fitted CDF stays a step at the shift instead of NaN
    let rate = 1.0 / (mean - min).max(1e-12);
    let dist = ShiftedExp::new(min, rate);
    let ks = ks_distance(samples, |t| 1.0 - dist.sf(t));
    ShiftedExpFit { dist, ks }
}

/// A fitted truncated Gaussian plus its goodness of fit.
#[derive(Debug, Clone)]
pub struct TruncatedGaussianFit {
    pub dist: TruncatedGaussian,
    pub ks: f64,
}

/// Moment fit of the paper's eq. 66 model (Fig. 3 overlay form).
pub fn fit_truncated_gaussian_ks(samples: &[f64]) -> TruncatedGaussianFit {
    let dist = fit_truncated_gaussian(samples);
    let ks = ks_distance(samples, |t| dist.cdf(t));
    TruncatedGaussianFit { dist, ks }
}

/// Which fitted family describes a channel better (smaller KS).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FitFamily {
    ShiftedExp,
    TruncatedGaussian,
}

impl std::fmt::Display for FitFamily {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            FitFamily::ShiftedExp => "shifted-exp",
            FitFamily::TruncatedGaussian => "trunc-gauss",
        })
    }
}

/// Both fits of one delay channel (comp or comm) of one worker.
#[derive(Debug, Clone)]
pub struct ChannelFit {
    /// Observations the fits were computed from.
    pub samples: usize,
    /// Sample mean (ms) — also the tiering feature for comp channels.
    pub mean_ms: f64,
    pub exp: ShiftedExpFit,
    pub tg: TruncatedGaussianFit,
}

impl ChannelFit {
    pub fn fit(samples: &[f64]) -> Self {
        assert!(samples.len() >= 2, "need ≥ 2 samples to fit");
        Self {
            samples: samples.len(),
            mean_ms: samples.iter().sum::<f64>() / samples.len() as f64,
            exp: fit_shifted_exp(samples),
            tg: fit_truncated_gaussian_ks(samples),
        }
    }

    /// The better-fitting family by KS distance (ties → the paper's
    /// truncated Gaussian).
    pub fn best(&self) -> FitFamily {
        if self.exp.ks < self.tg.ks {
            FitFamily::ShiftedExp
        } else {
            FitFamily::TruncatedGaussian
        }
    }

    /// KS distance of the better family.
    pub fn best_ks(&self) -> f64 {
        self.exp.ks.min(self.tg.ks)
    }
}

/// One worker's fitted delay model.
#[derive(Debug, Clone)]
pub struct WorkerFit {
    pub worker: usize,
    pub comp: ChannelFit,
    pub comm: ChannelFit,
}

/// Fleet-wide fit: per-worker models plus the fast/slow tier grouping.
#[derive(Debug, Clone)]
pub struct FleetFit {
    pub workers: Vec<WorkerFit>,
    /// `tier_of[w] ∈ {0 (fast), 1 (slow)}` from 2-means over the
    /// per-worker mean computation delay; all-0 when the fleet is
    /// effectively homogeneous (tier means within 10 %).
    pub tier_of: Vec<usize>,
    /// Per-worker round-to-round correlation strength: the log-std of
    /// the mean-1 log-normal common factor that best explains the
    /// excess between-round variance of the worker's round-mean task
    /// delay (0 when rounds look independent, or too few rounds to
    /// tell).
    pub sigma: Vec<f64>,
}

impl FleetFit {
    pub fn n(&self) -> usize {
        self.workers.len()
    }

    pub fn fast_workers(&self) -> Vec<usize> {
        (0..self.n()).filter(|&w| self.tier_of[w] == 0).collect()
    }

    pub fn slow_workers(&self) -> Vec<usize> {
        (0..self.n()).filter(|&w| self.tier_of[w] == 1).collect()
    }

    /// Mean per-task computation delay of each tier (ms); `None` for an
    /// empty tier.
    pub fn tier_mean_ms(&self, tier: usize) -> Option<f64> {
        let members: Vec<f64> = self
            .workers
            .iter()
            .zip(&self.tier_of)
            .filter(|(_, &t)| t == tier)
            .map(|(w, _)| w.comp.mean_ms)
            .collect();
        if members.is_empty() {
            None
        } else {
            Some(members.iter().sum::<f64>() / members.len() as f64)
        }
    }

    /// The fitted truncated-Gaussian fleet model (per-worker eq. 66
    /// parameters) — a [`crate::delay::DelayModel`] ready for replay.
    pub fn truncated_gaussian_model(&self) -> TruncatedGaussianModel {
        TruncatedGaussianModel::new(
            self.workers.iter().map(|w| w.comp.tg.dist.clone()).collect(),
            self.workers.iter().map(|w| w.comm.tg.dist.clone()).collect(),
            "fitted/trunc-gauss",
        )
    }

    /// The fitted per-worker shifted-exponential fleet model.
    pub fn shifted_exp_model(&self) -> crate::delay::PerWorkerShiftedExp {
        crate::delay::PerWorkerShiftedExp::new(
            self.workers.iter().map(|w| w.comp.exp.dist).collect(),
            self.workers.iter().map(|w| w.comm.exp.dist).collect(),
            "fitted/shifted-exp",
        )
    }

    /// Fleet-mean correlated log-std — [`WorkerCorrelated`] carries a
    /// single σ, so the replay twin uses the fleet average.
    pub fn mean_sigma(&self) -> f64 {
        if self.sigma.is_empty() {
            0.0
        } else {
            self.sigma.iter().sum::<f64>() / self.sigma.len() as f64
        }
    }

    /// The correlated replay twin: the truncated-Gaussian fleet model
    /// wrapped with the fitted per-round worker slowdown (`σ̂` at the
    /// fleet mean).  Marginal means are preserved (the factor is
    /// mean-1), so this only adds the round-to-round burstiness the
    /// independent models miss.
    pub fn correlated_model(&self) -> WorkerCorrelated<TruncatedGaussianModel> {
        WorkerCorrelated::new(self.truncated_gaussian_model(), self.mean_sigma())
    }
}

/// Deterministic 1-D 2-means over per-worker means: centers start at
/// the extremes, Lloyd iterations until stable.  Returns all-0 when
/// the converged centers sit within 10 % of each other (no meaningful
/// heterogeneity to act on).
fn two_tier(means: &[f64]) -> Vec<usize> {
    let n = means.len();
    let lo = means.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = means.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    if !(hi > lo) {
        return vec![0; n];
    }
    let (mut c0, mut c1) = (lo, hi);
    let mut assign = vec![0usize; n];
    for _ in 0..64 {
        let mut changed = false;
        for (w, &m) in means.iter().enumerate() {
            let t = usize::from((m - c0).abs() > (m - c1).abs());
            if assign[w] != t {
                assign[w] = t;
                changed = true;
            }
        }
        let mean_of = |tier: usize, fallback: f64| {
            let (mut sum, mut cnt) = (0.0, 0usize);
            for (w, &m) in means.iter().enumerate() {
                if assign[w] == tier {
                    sum += m;
                    cnt += 1;
                }
            }
            if cnt == 0 {
                fallback
            } else {
                sum / cnt as f64
            }
        };
        let (n0, n1) = (mean_of(0, c0), mean_of(1, c1));
        if !changed && n0 == c0 && n1 == c1 {
            break;
        }
        c0 = n0;
        c1 = n1;
    }
    // homogeneous fleet: collapse to a single tier
    if c1 <= c0 * 1.1 {
        return vec![0; n];
    }
    assign
}

/// Per-worker correlated-slowdown log-std from the between/within
/// variance decomposition (module docs): group the per-task computation
/// means by `(worker, round)`, estimate the between-round variance of
/// the round means, subtract the expected sampling noise of a round
/// mean (`v̂_t / c_t`, from rounds with ≥ 2 flushes), and invert
/// `Var(Z) = e^{σ²} − 1` of the mean-1 log-normal factor.  Workers with
/// fewer than two observed rounds get σ̂ = 0 — no evidence either way.
fn fit_sigma(store: &TraceStore) -> Vec<f64> {
    use std::collections::BTreeMap;
    let n = store.n_workers();
    // (sum, sum of squares, count) of per-task comp ms per (worker, round)
    let mut per: Vec<BTreeMap<u32, (f64, f64, usize)>> = vec![BTreeMap::new(); n];
    for ev in store.events() {
        let x = ev.compute_s * 1e3 / ev.tasks as f64;
        let cell = per[ev.worker as usize].entry(ev.round).or_insert((0.0, 0.0, 0));
        cell.0 += x;
        cell.1 += x * x;
        cell.2 += 1;
    }
    per.iter()
        .map(|rounds| {
            if rounds.len() < 2 {
                return 0.0;
            }
            let mut means = Vec::with_capacity(rounds.len());
            let (mut noise_sum, mut noise_cnt) = (0.0, 0usize);
            for &(s, ss, c) in rounds.values() {
                let cf = c as f64;
                means.push(s / cf);
                if c >= 2 {
                    // within-round sample variance → noise of the mean
                    let v = ((ss - s * s / cf) / (cf - 1.0)).max(0.0);
                    noise_sum += v / cf;
                    noise_cnt += 1;
                }
            }
            let t = means.len() as f64;
            let mu = means.iter().sum::<f64>() / t;
            if !(mu > 0.0) {
                return 0.0;
            }
            let var_between =
                means.iter().map(|m| (m - mu) * (m - mu)).sum::<f64>() / (t - 1.0);
            let noise = if noise_cnt > 0 {
                noise_sum / noise_cnt as f64
            } else {
                0.0
            };
            let excess = (var_between - noise).max(0.0);
            (1.0 + excess / (mu * mu)).ln().max(0.0).sqrt()
        })
        .collect()
}

/// Fit every worker's delay channels from a trace.  Every worker in
/// `[0, n_workers)` must have ≥ 2 computation and ≥ 2 communication
/// observations (fitting a worker the trace never saw would silently
/// invent a model).
pub fn fit_traces(store: &TraceStore) -> Result<FleetFit> {
    let n = store.n_workers();
    if n == 0 {
        bail!("cannot fit an empty trace");
    }
    // one pass over the events, not one per worker per channel
    let (comp_all, comm_all) = store.per_worker_ms();
    let mut workers = Vec::with_capacity(n);
    for (w, (comp, comm)) in comp_all.iter().zip(&comm_all).enumerate() {
        if comp.len() < 2 || comm.len() < 2 {
            bail!(
                "worker {w} has too few observations to fit ({} comp, {} comm; need ≥ 2 each) \
                 — record more rounds or window differently",
                comp.len(),
                comm.len()
            );
        }
        workers.push(WorkerFit {
            worker: w,
            comp: ChannelFit::fit(comp),
            comm: ChannelFit::fit(comm),
        });
    }
    let means: Vec<f64> = workers.iter().map(|w| w.comp.mean_ms).collect();
    let tier_of = two_tier(&means);
    let sigma = fit_sigma(store);
    Ok(FleetFit {
        workers,
        tier_of,
        sigma,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::record::TraceRecorder;
    use crate::util::rng::Rng;

    #[test]
    fn ks_of_perfect_cdf_is_small_and_of_wrong_cdf_is_large() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64 + 0.5) / 1000.0).collect();
        // uniform samples against the uniform CDF: D ≈ 1/(2n)
        let d = ks_distance(&xs, |t| t.clamp(0.0, 1.0));
        assert!(d < 2.0 / 1000.0, "{d}");
        // against a point mass at 0 the distance is ~1
        let d_bad = ks_distance(&xs, |_| 1.0);
        assert!(d_bad > 0.9, "{d_bad}");
    }

    #[test]
    fn shifted_exp_mle_recovers_parameters() {
        let truth = ShiftedExp::new(0.2, 4.0);
        let mut rng = Rng::seed_from_u64(7);
        let xs: Vec<f64> = (0..4000).map(|_| truth.sample(&mut rng)).collect();
        let fit = fit_shifted_exp(&xs);
        assert!((fit.dist.shift - 0.2).abs() < 0.01, "shift {}", fit.dist.shift);
        assert!((fit.dist.rate - 4.0).abs() / 4.0 < 0.1, "rate {}", fit.dist.rate);
        assert!(fit.ks < 0.03, "ks {}", fit.ks);
    }

    #[test]
    fn family_selection_matches_the_generator() {
        let mut rng = Rng::seed_from_u64(11);
        let exp = ShiftedExp::new(0.1, 3.0);
        let exp_xs: Vec<f64> = (0..3000).map(|_| exp.sample(&mut rng)).collect();
        let cf = ChannelFit::fit(&exp_xs);
        assert_eq!(
            cf.best(),
            FitFamily::ShiftedExp,
            "exp data: exp ks {} vs tg ks {}",
            cf.exp.ks,
            cf.tg.ks
        );

        let tg = TruncatedGaussian::symmetric(0.5, 0.2, 0.2);
        let tg_xs: Vec<f64> = (0..3000).map(|_| tg.sample(&mut rng)).collect();
        let cf = ChannelFit::fit(&tg_xs);
        assert_eq!(
            cf.best(),
            FitFamily::TruncatedGaussian,
            "tg data: exp ks {} vs tg ks {}",
            cf.exp.ks,
            cf.tg.ks
        );
        assert!((cf.tg.dist.mu - 0.5).abs() < 0.02, "mu {}", cf.tg.dist.mu);
    }

    #[test]
    fn two_tier_separates_and_collapses() {
        assert_eq!(two_tier(&[1.0, 1.1, 3.0, 3.2]), vec![0, 0, 1, 1]);
        assert_eq!(two_tier(&[2.0, 2.01, 1.99, 2.0]), vec![0; 4], "homogeneous");
        assert_eq!(two_tier(&[5.0]), vec![0]);
        // order independence of membership
        assert_eq!(two_tier(&[3.0, 1.0, 3.2, 1.1]), vec![1, 0, 1, 0]);
    }

    #[test]
    fn fit_traces_builds_replayable_models() {
        let mut rec = TraceRecorder::new("CS");
        let mut rng = Rng::seed_from_u64(3);
        for round in 0..200 {
            for w in 0..4usize {
                let comp = if w < 2 { 0.1 } else { 0.4 } + 0.02 * rng.f64();
                let comm = 0.5 + 0.1 * rng.f64();
                rec.push_slot(round, w, 0, comp, comm, false, round as u32);
            }
        }
        let fit = fit_traces(&rec.into_store()).unwrap();
        assert_eq!(fit.n(), 4);
        assert_eq!(fit.fast_workers(), vec![0, 1]);
        assert_eq!(fit.slow_workers(), vec![2, 3]);
        assert!(fit.tier_mean_ms(1).unwrap() > 3.0 * fit.tier_mean_ms(0).unwrap());
        // the fitted models are shaped for the fleet and sample sanely
        use crate::delay::DelayModel;
        let tg = fit.truncated_gaussian_model();
        let ex = fit.shifted_exp_model();
        let mut r2 = Rng::seed_from_u64(0);
        for model in [&tg as &dyn DelayModel, &ex] {
            let s = model.sample(4, 2, &mut r2);
            for w in 0..4 {
                for j in 0..2 {
                    assert!(s.comp(w, j) > 0.0 && s.comp(w, j) < 1.0, "{}", model.name());
                }
            }
        }
    }

    #[test]
    fn sigma_fit_separates_correlated_from_independent_workers() {
        // worker 0: every flush of a round shares a log-normal slowdown
        // (σ = 0.5); worker 1: iid flushes.  The decomposition must
        // attribute worker 0's between-round variance to the common
        // factor and see (almost) none at worker 1.
        let mut rec = TraceRecorder::new("CS");
        let mut rng = Rng::seed_from_u64(17);
        let gauss = |rng: &mut Rng| {
            let u1: f64 = rng.f64().max(1e-300);
            let u2: f64 = rng.f64();
            (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
        };
        for round in 0..400 {
            let z = (0.5 * gauss(&mut rng) - 0.125).exp();
            for flush in 0..4usize {
                let noise0 = 1.0 + 0.05 * rng.f64();
                rec.push_slot(round, 0, flush, 0.2 * z * noise0, 0.5, false, 0);
                let noise1 = 1.0 + 0.05 * rng.f64();
                rec.push_slot(round, 1, flush, 0.2 * noise1, 0.5, false, 0);
            }
        }
        let fit = fit_traces(&rec.into_store()).unwrap();
        assert!(
            fit.sigma[0] > 0.3,
            "correlated worker under-detected: σ̂ = {}",
            fit.sigma[0]
        );
        assert!(
            fit.sigma[1] < 0.1,
            "independent worker over-detected: σ̂ = {}",
            fit.sigma[1]
        );
        // the replay twin carries the fleet-mean σ and keeps the
        // fitted marginals underneath
        use crate::delay::DelayModel;
        let twin = fit.correlated_model();
        assert!(twin.name().starts_with("correlated(σ="), "{}", twin.name());
        assert!((twin.sigma - fit.mean_sigma()).abs() < 1e-12);
    }

    #[test]
    fn fit_rejects_unobserved_workers() {
        let mut rec = TraceRecorder::new("CS");
        rec.push_slot(0, 0, 0, 0.1, 0.5, false, 0);
        rec.push_slot(1, 0, 0, 0.1, 0.5, false, 1);
        rec.push_slot(0, 2, 0, 0.1, 0.5, false, 0); // worker 1 never observed
        rec.push_slot(1, 2, 0, 0.1, 0.5, false, 1);
        assert!(fit_traces(&rec.into_store()).is_err());
    }
}
