//! Trace subsystem — record → fit → replay, the fifth pillar next to
//! the engines ([`crate::sim`]), the scheme layer ([`crate::scheme`]),
//! the cluster data plane ([`crate::coordinator`]) and the adaptive
//! subsystem ([`crate::adaptive`]).
//!
//! The paper's headline results are *measured* on an EC2 cluster and
//! then explained through a statistical delay model; this module closes
//! that loop in-repo, turning the codebase into a calibrated digital
//! twin of a real fleet:
//!
//! * [`record`] — a canonical per-event trace format
//!   ([`TraceEvent`]: worker, round, slot, tasks, compute, comm, wire
//!   bytes, scheme, replanned-flag; versioned JSONL + compact binary,
//!   both bit-exact round-trips), a [`TraceStore`] with
//!   load/merge/filter/windowing, and the [`TraceRecorder`] tap fed by
//!   the cluster master (real socket timings, one event per `Result`
//!   frame) and by the simulator (censored slots — only deliveries the
//!   master saw before round completion, mirroring the adaptive
//!   estimator's causal view);
//! * [`fit`] — per-worker model fitting: shifted-exponential MLE and
//!   truncated-Gaussian moment fits with Kolmogorov–Smirnov
//!   goodness-of-fit against the empirical CDF, plus fast/slow tier
//!   grouping of heterogeneous fleets ([`fit_traces`] → [`FleetFit`]);
//! * [`replay`] — rebuild a delay substrate from the trace
//!   ([`crate::delay::EmpiricalModel`] bootstrap, or the fitted
//!   parametric fleets) and run the whole scheme × policy matrix
//!   against it ([`replay::replay`]), bit-reproducibly under a fixed
//!   seed with an FNV completion digest as the determinism pin.
//!
//! CLI: `straggler trace record|fit|replay`, plus `sim --from-trace`
//! (replay inline) and `sim --record` / `train --record` (capture).
//! The committed fixture `rust/tests/fixtures/fleet_trace.jsonl` makes
//! the loop runnable end-to-end without a cluster; EXPERIMENTS.md
//! §Traces documents the schema and the fit math.
//!
//! Closing the loop this way follows how Ozfatura, Ulukus & Gündüz
//! (arXiv:2004.04948) treat the communication–computation latency
//! trade-off on measured fleets and how Egger, Kas Hanna & Bitar
//! (arXiv:2304.08589) drive adaptive load from estimated straggling
//! behavior.

pub mod fit;
pub mod record;
pub mod replay;

pub use fit::{
    fit_shifted_exp, fit_traces, fit_truncated_gaussian_ks, ks_distance, ChannelFit, FitFamily,
    FleetFit, ShiftedExpFit, TruncatedGaussianFit, WorkerFit,
};
pub use record::{TraceEvent, TraceRecorder, TraceStore, BINARY_MAGIC, TRACE_FORMAT};
pub use replay::{
    default_matrix_schemes, empirical_model, model_from_trace, replay, DecodeCacheReplay,
    ReplayCell, ReplayConfig, ReplayOutcome, ReplaySource,
};
