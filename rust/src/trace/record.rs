//! The canonical trace format: per-event delay records, a versioned
//! JSONL codec (human-greppable, diff-friendly) and a compact
//! little-endian binary codec (bulk storage), plus the [`TraceStore`]
//! container with load/merge/filter/windowing and the [`TraceRecorder`]
//! tap both execution paths feed.
//!
//! One [`TraceEvent`] is one delivered **message**: for the live
//! cluster that is one `Result` frame (a flush of `tasks` tasks, the
//! frame's measured `comp_us` and wire delay, and its on-wire size);
//! for the simulator it is one censored slot (`tasks = 1`, `bytes = 0`
//! — no wire).  Delays are stored in **seconds** (SI units on disk; the
//! in-memory engine convention stays milliseconds — the accessors
//! convert), and `compute_s` always covers the *whole* event, so
//! per-task attribution divides by `tasks` exactly like
//! [`crate::adaptive::DelayEstimator::observe_flush`].

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Format tag of the JSONL header line and the binary magic version.
/// The tag is unchanged by the async θ-version extension: the per-event
/// `version` key is *optional* on read (absent = `0`, the synchronous
/// tag of round 0 — pre-async traces stay loadable verbatim) and is
/// always written, so v4-era traces are self-describing.
pub const TRACE_FORMAT: &str = "straggler-trace/v1";

/// Magic prefix of the binary codec (7 bytes + 1 version byte).
/// `\x03` adds the measured worker-queue delay (`queue_s`); `\x02`
/// (θ-version tag, no queue) and `\x01` (neither) traces are still
/// accepted and load with the missing fields zeroed.
pub const BINARY_MAGIC: &[u8; 8] = b"STRGTRC\x03";

/// The pre-latency-anatomy binary magic — readable, never written.
pub const BINARY_MAGIC_V2: &[u8; 8] = b"STRGTRC\x02";

/// The pre-async binary magic — readable, never written.
pub const BINARY_MAGIC_V1: &[u8; 8] = b"STRGTRC\x01";

/// One recorded delivery.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Worker index `i ∈ [0, n)`.
    pub worker: u32,
    /// DGD round the delivery belongs to.
    pub round: u32,
    /// Message index within `(worker, round)` for cluster traces; the
    /// computation-slot index `j` for per-slot simulator traces.
    pub slot: u32,
    /// Tasks covered by the event (`1` = per-slot record; a GC(s)
    /// flush covers up to `s`).
    pub tasks: u32,
    /// Computation time covered by the event, in **seconds** (the
    /// frame's `comp_us`; divide by `tasks` for per-task attribution).
    pub compute_s: f64,
    /// Communication delay of the delivery, in **seconds**.
    pub comm_s: f64,
    /// Worker-side queueing delay of the delivery (flush enqueue →
    /// wire send, measured on the worker's own clock), in **seconds**.
    /// `0` for simulated traces and for recordings made before the
    /// protocol carried worker timestamps.
    pub queue_s: f64,
    /// On-wire frame bytes (length prefix + payload); `0` for
    /// simulated traces.
    pub bytes: u64,
    /// Scheme label the trace was recorded under (e.g. `"GC(2)"`).
    pub scheme: String,
    /// Whether an adaptive policy changed the plan for this round.
    pub replanned: bool,
    /// θ-version the round was computed against (protocol v4's
    /// per-frame tag).  Synchronous rounds carry `version == round`
    /// (staleness gap 0); a bounded-staleness pipeline carries
    /// `round − version ≤ S − 1`.  Pre-async traces load as `0`.
    pub version: u32,
}

impl TraceEvent {
    fn validate(&self) -> Result<()> {
        if self.tasks == 0 {
            bail!("trace event covers zero tasks");
        }
        if !(self.compute_s.is_finite() && self.compute_s >= 0.0) {
            bail!("trace event compute_s must be finite and ≥ 0, got {}", self.compute_s);
        }
        if !(self.comm_s.is_finite() && self.comm_s >= 0.0) {
            bail!("trace event comm_s must be finite and ≥ 0, got {}", self.comm_s);
        }
        if !(self.queue_s.is_finite() && self.queue_s >= 0.0) {
            bail!("trace event queue_s must be finite and ≥ 0, got {}", self.queue_s);
        }
        if self.scheme.is_empty() {
            bail!("trace event needs a scheme label");
        }
        if self.version > self.round {
            bail!(
                "trace event θ-version {} is ahead of its round {} — a round can \
                 never be computed against a future model",
                self.version,
                self.round
            );
        }
        Ok(())
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("worker", Json::Num(self.worker as f64)),
            ("round", Json::Num(self.round as f64)),
            ("slot", Json::Num(self.slot as f64)),
            ("tasks", Json::Num(self.tasks as f64)),
            ("compute_s", Json::Num(self.compute_s)),
            ("comm_s", Json::Num(self.comm_s)),
            ("queue_s", Json::Num(self.queue_s)),
            ("bytes", Json::Num(self.bytes as f64)),
            ("scheme", Json::Str(self.scheme.clone())),
            ("replanned", Json::Bool(self.replanned)),
            ("version", Json::Num(self.version as f64)),
        ])
    }

    fn from_json(v: &Json) -> Result<Self> {
        let u32_field = |key: &str| -> Result<u32> {
            v.get(key)
                .and_then(Json::as_usize)
                .and_then(|x| u32::try_from(x).ok())
                .with_context(|| format!("trace event `{key}` must be a u32"))
        };
        let f64_field = |key: &str| -> Result<f64> {
            v.get(key)
                .and_then(Json::as_f64)
                .with_context(|| format!("trace event `{key}` must be a number"))
        };
        let ev = Self {
            worker: u32_field("worker")?,
            round: u32_field("round")?,
            slot: u32_field("slot")?,
            tasks: u32_field("tasks")?,
            compute_s: f64_field("compute_s")?,
            comm_s: f64_field("comm_s")?,
            // optional: pre-latency-anatomy traces carry no worker-side
            // queue measurement — they load as 0
            queue_s: match v.get("queue_s") {
                None => 0.0,
                Some(x) => x.as_f64().context("trace event `queue_s` must be a number")?,
            },
            bytes: v
                .get("bytes")
                .and_then(Json::as_usize)
                .context("trace event `bytes` must be a non-negative integer")?
                as u64,
            scheme: v
                .get("scheme")
                .and_then(Json::as_str)
                .context("trace event `scheme` must be a string")?
                .to_string(),
            replanned: v
                .get("replanned")
                .and_then(Json::as_bool)
                .context("trace event `replanned` must be a bool")?,
            // optional: pre-async traces have no θ-version tag — they
            // load as 0 (the synchronous tag of round 0)
            version: match v.get("version") {
                None => 0,
                Some(x) => x
                    .as_usize()
                    .and_then(|u| u32::try_from(u).ok())
                    .context("trace event `version` must be a u32")?,
            },
        };
        ev.validate()?;
        Ok(ev)
    }
}

/// An ordered bag of trace events with the trace-subsystem plumbing:
/// codecs, merge, filtering, round windowing, and the per-worker delay
/// extraction the fitting layer consumes.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceStore {
    events: Vec<TraceEvent>,
    /// Fleet size declared by the recorder (`Some(n)`); without it the
    /// fleet is inferred as `max worker + 1`, which silently drops a
    /// trailing worker whose deliveries were all censored — the taps
    /// therefore always declare.
    declared_workers: Option<u32>,
}

impl TraceStore {
    pub fn new(events: Vec<TraceEvent>) -> Result<Self> {
        for ev in &events {
            ev.validate()?;
        }
        Ok(Self {
            events,
            declared_workers: None,
        })
    }

    /// Declare the true fleet size (kept through codecs, merge and
    /// filtering): a worker the trace never observed then *fails*
    /// fitting/replay loudly instead of shrinking the fleet.
    pub fn with_fleet(mut self, n: usize) -> Self {
        self.declared_workers = Some(n as u32);
        self
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Fleet size: the recorder's declaration when present (never less
    /// than what the events imply), else `max worker + 1`.
    pub fn n_workers(&self) -> usize {
        let implied = self
            .events
            .iter()
            .map(|e| e.worker as usize + 1)
            .max()
            .unwrap_or(0);
        implied.max(self.declared_workers.unwrap_or(0) as usize)
    }

    /// Rounds covered (`max round + 1`).
    pub fn rounds(&self) -> usize {
        self.events
            .iter()
            .map(|e| e.round as usize + 1)
            .max()
            .unwrap_or(0)
    }

    /// Distinct scheme labels, first-seen order.
    pub fn schemes(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for ev in &self.events {
            if !out.iter().any(|s| *s == ev.scheme) {
                out.push(ev.scheme.clone());
            }
        }
        out
    }

    /// Total on-wire bytes recorded.
    pub fn total_bytes(&self) -> u64 {
        self.events.iter().map(|e| e.bytes).sum()
    }

    /// Append another trace's events (e.g. several recorded runs of the
    /// same fleet).  Event order within each store is preserved;
    /// `other`'s events follow `self`'s, and the larger declared fleet
    /// wins.
    pub fn merge(&mut self, other: TraceStore) {
        self.events.extend(other.events);
        self.declared_workers = self.declared_workers.max(other.declared_workers);
    }

    /// Events satisfying `pred`, in order (the declared fleet size is
    /// kept — filtering observations does not shrink the fleet).
    pub fn filter(&self, pred: impl Fn(&TraceEvent) -> bool) -> TraceStore {
        TraceStore {
            events: self.events.iter().filter(|e| pred(e)).cloned().collect(),
            declared_workers: self.declared_workers,
        }
    }

    /// Events recorded under one scheme label.
    pub fn filter_scheme(&self, scheme: &str) -> TraceStore {
        self.filter(|e| e.scheme == scheme)
    }

    /// Events whose round lies in `[lo, hi)` — e.g. to drop warmup
    /// rounds before fitting, or to fit drifting fleets piecewise.
    pub fn window(&self, lo: usize, hi: usize) -> TraceStore {
        self.filter(|e| (lo..hi).contains(&(e.round as usize)))
    }

    /// Per-task computation delays of `worker` in **milliseconds**:
    /// each event contributes `tasks` observations of
    /// `compute_s / tasks` — the same even attribution the adaptive
    /// estimator uses for flush-grouped measurements.
    pub fn comp_ms(&self, worker: usize) -> Vec<f64> {
        let mut out = Vec::new();
        for ev in &self.events {
            if ev.worker as usize == worker {
                let per_task = ev.compute_s * 1e3 / ev.tasks as f64;
                out.resize(out.len() + ev.tasks as usize, per_task);
            }
        }
        out
    }

    /// Per-message communication delays of `worker` in milliseconds
    /// (one observation per event — comm rides messages, not tasks).
    pub fn comm_ms(&self, worker: usize) -> Vec<f64> {
        self.events
            .iter()
            .filter(|e| e.worker as usize == worker)
            .map(|e| e.comm_s * 1e3)
            .collect()
    }

    /// Per-message worker-queue delays of `worker` in milliseconds
    /// (one observation per event, like [`TraceStore::comm_ms`]; all
    /// zero for simulated and pre-latency-anatomy traces).
    pub fn queue_ms(&self, worker: usize) -> Vec<f64> {
        self.events
            .iter()
            .filter(|e| e.worker as usize == worker)
            .map(|e| e.queue_s * 1e3)
            .collect()
    }

    /// Every worker's `(comp, comm)` millisecond samples in one pass
    /// over the events — what the fitting and replay layers consume
    /// (the per-worker accessors above are O(events) *each*; on an
    /// operational million-event trace a per-worker loop over them
    /// would be O(workers × events)).
    pub fn per_worker_ms(&self) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
        let n = self.n_workers();
        let mut comp: Vec<Vec<f64>> = vec![Vec::new(); n];
        let mut comm: Vec<Vec<f64>> = vec![Vec::new(); n];
        for ev in &self.events {
            let w = ev.worker as usize;
            let per_task = ev.compute_s * 1e3 / ev.tasks as f64;
            let c = &mut comp[w];
            c.resize(c.len() + ev.tasks as usize, per_task);
            comm[w].push(ev.comm_s * 1e3);
        }
        (comp, comm)
    }

    // ---- JSONL codec -------------------------------------------------------

    /// Serialize as versioned JSONL: a header line
    /// `{"format": "straggler-trace/v1", "events": N, "workers": n}`
    /// (`workers` only when declared) followed by one compact JSON
    /// object per event.
    pub fn to_jsonl(&self) -> String {
        let mut header = vec![
            ("format", Json::Str(TRACE_FORMAT.into())),
            ("events", Json::Num(self.events.len() as f64)),
        ];
        if let Some(n) = self.declared_workers {
            header.push(("workers", Json::Num(n as f64)));
        }
        let mut out = String::new();
        out.push_str(&Json::obj(header).to_string_compact());
        out.push('\n');
        for ev in &self.events {
            out.push_str(&ev.to_json().to_string_compact());
            out.push('\n');
        }
        out
    }

    pub fn from_jsonl(text: &str) -> Result<Self> {
        let mut lines = text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty());
        let (_, header) = lines.next().context("empty trace file")?;
        let header = Json::parse(header).context("trace header is not JSON")?;
        let format = header
            .get("format")
            .and_then(Json::as_str)
            .context("trace header missing `format`")?;
        if format != TRACE_FORMAT {
            bail!("unsupported trace format {format:?} (this build reads {TRACE_FORMAT:?})");
        }
        let declared = header.get("events").and_then(Json::as_usize);
        let declared_workers = header
            .get("workers")
            .and_then(Json::as_usize)
            .map(|n| n as u32);
        let mut events = Vec::new();
        for (lineno, line) in lines {
            let v = Json::parse(line)
                .with_context(|| format!("trace line {} is not JSON", lineno + 1))?;
            events.push(
                TraceEvent::from_json(&v)
                    .map_err(|e| e.context(format!("trace line {}", lineno + 1)))?,
            );
        }
        if let Some(want) = declared {
            if want != events.len() {
                bail!(
                    "trace header declares {want} events but the file holds {} — truncated?",
                    events.len()
                );
            }
        }
        Ok(Self {
            events,
            declared_workers,
        })
    }

    // ---- binary codec ------------------------------------------------------

    /// Compact little-endian binary form: magic, declared fleet size
    /// (`0` = undeclared), interned scheme table, then fixed-width
    /// records.  `f64` delays round-trip bit-exactly
    /// (`to_le_bytes`/`from_le_bytes`).
    pub fn to_binary(&self) -> Vec<u8> {
        let schemes = self.schemes();
        let mut out = Vec::with_capacity(20 + self.events.len() * 53);
        out.extend_from_slice(BINARY_MAGIC);
        out.extend_from_slice(&self.declared_workers.unwrap_or(0).to_le_bytes());
        out.extend_from_slice(&(schemes.len() as u32).to_le_bytes());
        for s in &schemes {
            out.extend_from_slice(&(s.len() as u32).to_le_bytes());
            out.extend_from_slice(s.as_bytes());
        }
        out.extend_from_slice(&(self.events.len() as u64).to_le_bytes());
        for ev in &self.events {
            let scheme_idx = schemes.iter().position(|s| *s == ev.scheme).expect("interned") as u32;
            out.extend_from_slice(&ev.worker.to_le_bytes());
            out.extend_from_slice(&ev.round.to_le_bytes());
            out.extend_from_slice(&ev.version.to_le_bytes());
            out.extend_from_slice(&ev.slot.to_le_bytes());
            out.extend_from_slice(&ev.tasks.to_le_bytes());
            out.extend_from_slice(&scheme_idx.to_le_bytes());
            out.extend_from_slice(&ev.bytes.to_le_bytes());
            out.push(ev.replanned as u8);
            out.extend_from_slice(&ev.compute_s.to_le_bytes());
            out.extend_from_slice(&ev.comm_s.to_le_bytes());
            out.extend_from_slice(&ev.queue_s.to_le_bytes());
        }
        out
    }

    pub fn from_binary(bytes: &[u8]) -> Result<Self> {
        fn take<'a>(bytes: &'a [u8], pos: &mut usize, len: usize) -> Result<&'a [u8]> {
            let end = pos
                .checked_add(len)
                .filter(|&e| e <= bytes.len())
                .context("truncated binary trace")?;
            let out = &bytes[*pos..end];
            *pos = end;
            Ok(out)
        }
        fn u32_at(bytes: &[u8], pos: &mut usize) -> Result<u32> {
            Ok(u32::from_le_bytes(take(bytes, pos, 4)?.try_into().unwrap()))
        }
        fn u64_at(bytes: &[u8], pos: &mut usize) -> Result<u64> {
            Ok(u64::from_le_bytes(take(bytes, pos, 8)?.try_into().unwrap()))
        }
        fn f64_at(bytes: &[u8], pos: &mut usize) -> Result<f64> {
            Ok(f64::from_le_bytes(take(bytes, pos, 8)?.try_into().unwrap()))
        }
        let mut pos = 0usize;
        let magic = take(bytes, &mut pos, BINARY_MAGIC.len())?;
        // v3 carries the worker-queue delay, v2 the per-event θ-version
        // tag; older traces are still readable — their events load with
        // the missing fields zeroed
        let (has_version, has_queue) = if magic == BINARY_MAGIC {
            (true, true)
        } else if magic == BINARY_MAGIC_V2 {
            (true, false)
        } else if magic == BINARY_MAGIC_V1 {
            (false, false)
        } else {
            bail!("not a binary straggler trace (bad magic)");
        };
        let declared_workers = match u32_at(bytes, &mut pos)? {
            0 => None,
            n => Some(n),
        };
        let n_schemes = u32_at(bytes, &mut pos)? as usize;
        let mut schemes = Vec::with_capacity(n_schemes);
        for _ in 0..n_schemes {
            let len = u32_at(bytes, &mut pos)? as usize;
            let raw = take(bytes, &mut pos, len)?;
            schemes.push(
                std::str::from_utf8(raw)
                    .context("scheme label is not UTF-8")?
                    .to_string(),
            );
        }
        let count = u64_at(bytes, &mut pos)? as usize;
        // cap the pre-allocation: a corrupt header must not OOM the loader
        let mut events = Vec::with_capacity(count.min(1 << 20));
        for _ in 0..count {
            let worker = u32_at(bytes, &mut pos)?;
            let round = u32_at(bytes, &mut pos)?;
            let version = if has_version { u32_at(bytes, &mut pos)? } else { 0 };
            let slot = u32_at(bytes, &mut pos)?;
            let tasks = u32_at(bytes, &mut pos)?;
            let scheme_idx = u32_at(bytes, &mut pos)? as usize;
            let wire = u64_at(bytes, &mut pos)?;
            let replanned = take(bytes, &mut pos, 1)?[0] != 0;
            let compute_s = f64_at(bytes, &mut pos)?;
            let comm_s = f64_at(bytes, &mut pos)?;
            let queue_s = if has_queue { f64_at(bytes, &mut pos)? } else { 0.0 };
            let ev = TraceEvent {
                worker,
                round,
                slot,
                tasks,
                compute_s,
                comm_s,
                queue_s,
                bytes: wire,
                scheme: schemes
                    .get(scheme_idx)
                    .context("scheme index out of table")?
                    .clone(),
                replanned,
                version,
            };
            ev.validate()?;
            events.push(ev);
        }
        if pos != bytes.len() {
            bail!("trailing bytes after the declared {count} events");
        }
        Ok(Self {
            events,
            declared_workers,
        })
    }

    // ---- file plumbing -----------------------------------------------------

    /// Load a trace, sniffing the codec: binary magic → binary, else
    /// JSONL.
    pub fn load(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        if bytes.starts_with(BINARY_MAGIC)
            || bytes.starts_with(BINARY_MAGIC_V2)
            || bytes.starts_with(BINARY_MAGIC_V1)
        {
            Self::from_binary(&bytes)
        } else {
            let text = std::str::from_utf8(&bytes)
                .with_context(|| format!("trace {} is neither binary nor UTF-8", path.display()))?;
            Self::from_jsonl(text)
        }
    }

    /// Save, choosing the codec by extension: `.bin` → binary, anything
    /// else → JSONL.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
        let bytes = if path.extension().is_some_and(|e| e == "bin") {
            self.to_binary()
        } else {
            self.to_jsonl().into_bytes()
        };
        std::fs::write(path, bytes).with_context(|| format!("writing trace {}", path.display()))
    }
}

/// The capture tap both execution paths feed: the cluster master pushes
/// one flush per received `Result` frame, the simulator pushes censored
/// slots (only deliveries the master actually saw before the round
/// completed — the same causal view the adaptive estimator gets).
#[derive(Debug, Clone)]
pub struct TraceRecorder {
    scheme: String,
    fleet: Option<u32>,
    events: Vec<TraceEvent>,
}

impl TraceRecorder {
    pub fn new(scheme: impl Into<String>) -> Self {
        Self {
            scheme: scheme.into(),
            fleet: None,
            events: Vec::new(),
        }
    }

    /// A recorder that declares the fleet size up front — what both
    /// execution taps use, so a worker whose deliveries were all
    /// censored still counts toward the recorded fleet (fitting it
    /// then fails loudly instead of silently shrinking `n`).
    pub fn with_fleet(scheme: impl Into<String>, n: usize) -> Self {
        Self {
            scheme: scheme.into(),
            fleet: Some(n as u32),
            events: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Record one simulated slot delivery (ms in, seconds stored).
    ///
    /// Panics on a non-finite/negative delay: every load path
    /// validates, so an invalid measurement must fail at the tap — not
    /// after the recording was saved and became permanently unloadable.
    #[allow(clippy::too_many_arguments)]
    pub fn push_slot(
        &mut self,
        round: usize,
        worker: usize,
        slot: usize,
        comp_ms: f64,
        comm_ms: f64,
        replanned: bool,
        version: u32,
    ) {
        let ev = TraceEvent {
            worker: worker as u32,
            round: round as u32,
            slot: slot as u32,
            tasks: 1,
            compute_s: comp_ms * 1e-3,
            comm_s: comm_ms * 1e-3,
            queue_s: 0.0,
            bytes: 0,
            scheme: self.scheme.clone(),
            replanned,
            version,
        };
        ev.validate().expect("recorded slot event must be loadable");
        self.events.push(ev);
    }

    /// Record one measured cluster flush: `tasks` tasks computed in
    /// `comp_total_ms`, delivered with `comm_ms` of wire delay after
    /// `queue_ms` of worker-side queueing, in a `bytes`-byte frame;
    /// `msg_idx` is the message's index within the worker's round.
    /// Panics on an invalid frame (zero tasks, non-finite/negative
    /// delay) — same tap-time guarantee as [`TraceRecorder::push_slot`].
    #[allow(clippy::too_many_arguments)]
    pub fn push_flush(
        &mut self,
        round: usize,
        worker: usize,
        msg_idx: usize,
        tasks: usize,
        comp_total_ms: f64,
        comm_ms: f64,
        queue_ms: f64,
        bytes: usize,
        replanned: bool,
        version: u32,
    ) {
        let ev = TraceEvent {
            worker: worker as u32,
            round: round as u32,
            slot: msg_idx as u32,
            tasks: tasks as u32,
            compute_s: comp_total_ms * 1e-3,
            comm_s: comm_ms * 1e-3,
            queue_s: queue_ms * 1e-3,
            bytes: bytes as u64,
            scheme: self.scheme.clone(),
            replanned,
            version,
        };
        ev.validate().expect("recorded flush event must be loadable");
        self.events.push(ev);
    }

    pub fn into_store(self) -> TraceStore {
        TraceStore {
            events: self.events,
            declared_workers: self.fleet,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_store() -> TraceStore {
        let mut rec = TraceRecorder::new("GC(2)");
        rec.push_flush(0, 0, 0, 2, 3.25, 5.5, 0.75, 2088, false, 0);
        rec.push_flush(0, 1, 0, 2, 9.75, 6.25, 0.5, 2088, false, 0);
        rec.push_slot(1, 0, 0, 1.625, 5.0, true, 1);
        rec.into_store()
    }

    #[test]
    fn recorder_units_and_shape() {
        let s = sample_store();
        assert_eq!(s.len(), 3);
        assert_eq!(s.n_workers(), 2);
        assert_eq!(s.rounds(), 2);
        assert_eq!(s.schemes(), vec!["GC(2)".to_string()]);
        assert_eq!(s.total_bytes(), 2 * 2088);
        // flush of 2 tasks in 3.25 ms → two per-task observations of 1.625 ms
        assert_eq!(s.comp_ms(0), vec![1.625, 1.625, 1.625]);
        // comm is per message: one observation per event
        assert_eq!(s.comm_ms(0), vec![5.5, 5.0]);
        assert_eq!(s.comm_ms(1), vec![6.25]);
        // queue rides messages too; simulated slots record zero
        assert_eq!(s.queue_ms(0), vec![0.75, 0.0]);
        assert_eq!(s.queue_ms(1), vec![0.5]);
    }

    #[test]
    fn jsonl_roundtrip_is_bit_identical() {
        let s = sample_store();
        let text = s.to_jsonl();
        assert!(text.starts_with("{\"format\":\"straggler-trace/v1\""));
        let back = TraceStore::from_jsonl(&text).unwrap();
        assert_eq!(back, s);
        for (a, b) in back.events().iter().zip(s.events()) {
            assert_eq!(a.compute_s.to_bits(), b.compute_s.to_bits());
            assert_eq!(a.comm_s.to_bits(), b.comm_s.to_bits());
        }
    }

    #[test]
    fn binary_roundtrip_is_bit_identical() {
        let s = sample_store();
        let bin = s.to_binary();
        assert!(bin.starts_with(BINARY_MAGIC));
        let back = TraceStore::from_binary(&bin).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn jsonl_rejects_malformed() {
        assert!(TraceStore::from_jsonl("").is_err(), "empty");
        assert!(
            TraceStore::from_jsonl("{\"format\":\"other/v9\"}\n").is_err(),
            "wrong format tag"
        );
        let s = sample_store();
        let mut text = s.to_jsonl();
        text.push_str("{\"worker\":0}\n");
        assert!(TraceStore::from_jsonl(&text).is_err(), "short event line");
        // truncation detection via the declared count
        let truncated: String = s
            .to_jsonl()
            .lines()
            .take(2)
            .map(|l| format!("{l}\n"))
            .collect();
        assert!(TraceStore::from_jsonl(&truncated).is_err(), "truncated body");
    }

    #[test]
    fn binary_rejects_malformed() {
        let s = sample_store();
        let bin = s.to_binary();
        assert!(TraceStore::from_binary(&bin[..bin.len() - 3]).is_err(), "truncated");
        assert!(TraceStore::from_binary(b"NOPE").is_err(), "bad magic");
        let mut extra = bin.clone();
        extra.push(7);
        assert!(TraceStore::from_binary(&extra).is_err(), "trailing bytes");
    }

    #[test]
    fn declared_fleet_survives_codecs_and_filtering() {
        // worker 3 exists but was never observed (fully censored): the
        // declared fleet keeps it in n_workers through both codecs,
        // merge and windowing — downstream fitting then fails loudly
        // instead of modeling a 3-worker fleet
        let mut rec = TraceRecorder::with_fleet("CS", 4);
        rec.push_slot(0, 0, 0, 0.1, 0.5, false, 0);
        rec.push_slot(0, 2, 0, 0.1, 0.5, false, 0);
        let store = rec.into_store();
        assert_eq!(store.n_workers(), 4);
        assert_eq!(TraceStore::from_jsonl(&store.to_jsonl()).unwrap(), store);
        assert_eq!(TraceStore::from_binary(&store.to_binary()).unwrap(), store);
        assert!(store.to_jsonl().starts_with(
            "{\"format\":\"straggler-trace/v1\",\"events\":2,\"workers\":4}"
        ));
        assert_eq!(store.window(0, 1).n_workers(), 4);
        assert_eq!(store.filter_scheme("CS").n_workers(), 4);
        let mut merged = TraceStore::new(vec![]).unwrap();
        merged.merge(store.clone());
        assert_eq!(merged.n_workers(), 4);
        // the undeclared path still infers from events, and an explicit
        // declaration never *shrinks* below what the events imply
        assert_eq!(sample_store().n_workers(), 2);
        assert_eq!(sample_store().with_fleet(1).n_workers(), 2);
    }

    #[test]
    fn filter_window_merge() {
        let s = sample_store();
        assert_eq!(s.window(0, 1).len(), 2);
        assert_eq!(s.window(1, 2).len(), 1);
        assert_eq!(s.filter_scheme("GC(2)").len(), 3);
        assert_eq!(s.filter_scheme("CS").len(), 0);
        let mut merged = s.clone();
        merged.merge(s.clone());
        assert_eq!(merged.len(), 6);
        assert_eq!(merged.n_workers(), 2);
    }

    #[test]
    fn event_validation_rejects_bad_delays() {
        let mut ev = sample_store().events()[0].clone();
        ev.compute_s = f64::NAN;
        assert!(TraceStore::new(vec![ev]).is_err());
        let mut ev = sample_store().events()[0].clone();
        ev.queue_s = -1.0;
        assert!(TraceStore::new(vec![ev]).is_err());
        let mut ev = sample_store().events()[0].clone();
        ev.tasks = 0;
        assert!(TraceStore::new(vec![ev]).is_err());
        // a θ-version ahead of its round is a corrupt tag
        let mut ev = sample_store().events()[0].clone();
        ev.round = 3;
        ev.version = 4;
        assert!(TraceStore::new(vec![ev]).is_err());
    }

    #[test]
    fn version_tags_roundtrip_and_default_to_zero() {
        // an async recording: round 4 computed against θ-version 2
        let mut rec = TraceRecorder::with_fleet("CS@s3", 2);
        rec.push_slot(4, 0, 0, 0.1, 0.5, false, 2);
        rec.push_flush(4, 1, 0, 2, 0.2, 0.5, 0.1, 1024, false, 2);
        let store = rec.into_store();
        for back in [
            TraceStore::from_jsonl(&store.to_jsonl()).unwrap(),
            TraceStore::from_binary(&store.to_binary()).unwrap(),
        ] {
            assert_eq!(back, store);
            assert!(back.events().iter().all(|e| e.version == 2));
        }
        // a pre-async JSONL line (no `version` key) loads as version 0
        let legacy = format!(
            "{}\n{}\n",
            "{\"format\":\"straggler-trace/v1\",\"events\":1}",
            "{\"worker\":0,\"round\":7,\"slot\":0,\"tasks\":1,\"compute_s\":0.001,\
             \"comm_s\":0.002,\"bytes\":0,\"scheme\":\"CS\",\"replanned\":false}"
        );
        let back = TraceStore::from_jsonl(&legacy).unwrap();
        assert_eq!(back.events()[0].version, 0);
        // ...and no `queue_s` key either — loads as zero queueing
        assert_eq!(back.events()[0].queue_s, 0.0);
    }

    #[test]
    fn legacy_v1_binary_traces_still_load() {
        // hand-build a v1 (pre-version-tag) binary trace: one CS event,
        // worker 0, round 7 — must load with version = 0
        let mut bin = Vec::new();
        bin.extend_from_slice(BINARY_MAGIC_V1);
        bin.extend_from_slice(&0u32.to_le_bytes()); // fleet undeclared
        bin.extend_from_slice(&1u32.to_le_bytes()); // one scheme
        bin.extend_from_slice(&2u32.to_le_bytes());
        bin.extend_from_slice(b"CS");
        bin.extend_from_slice(&1u64.to_le_bytes()); // one event
        bin.extend_from_slice(&0u32.to_le_bytes()); // worker
        bin.extend_from_slice(&7u32.to_le_bytes()); // round (no version!)
        bin.extend_from_slice(&0u32.to_le_bytes()); // slot
        bin.extend_from_slice(&1u32.to_le_bytes()); // tasks
        bin.extend_from_slice(&0u32.to_le_bytes()); // scheme idx
        bin.extend_from_slice(&0u64.to_le_bytes()); // bytes
        bin.push(0); // replanned
        bin.extend_from_slice(&0.001f64.to_le_bytes());
        bin.extend_from_slice(&0.002f64.to_le_bytes());
        let back = TraceStore::from_binary(&bin).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back.events()[0].round, 7);
        assert_eq!(back.events()[0].version, 0);
        assert_eq!(back.events()[0].queue_s, 0.0);
        // and re-saving upgrades it to the current magic
        assert!(back.to_binary().starts_with(BINARY_MAGIC));
    }

    #[test]
    fn legacy_v2_binary_traces_still_load() {
        // hand-build a v2 (θ-version tag, no queue_s) binary trace: one
        // CS event at round 7 / version 3 — must load with queue_s = 0
        let mut bin = Vec::new();
        bin.extend_from_slice(BINARY_MAGIC_V2);
        bin.extend_from_slice(&0u32.to_le_bytes()); // fleet undeclared
        bin.extend_from_slice(&1u32.to_le_bytes()); // one scheme
        bin.extend_from_slice(&2u32.to_le_bytes());
        bin.extend_from_slice(b"CS");
        bin.extend_from_slice(&1u64.to_le_bytes()); // one event
        bin.extend_from_slice(&0u32.to_le_bytes()); // worker
        bin.extend_from_slice(&7u32.to_le_bytes()); // round
        bin.extend_from_slice(&3u32.to_le_bytes()); // version
        bin.extend_from_slice(&0u32.to_le_bytes()); // slot
        bin.extend_from_slice(&1u32.to_le_bytes()); // tasks
        bin.extend_from_slice(&0u32.to_le_bytes()); // scheme idx
        bin.extend_from_slice(&0u64.to_le_bytes()); // bytes
        bin.push(0); // replanned
        bin.extend_from_slice(&0.001f64.to_le_bytes());
        bin.extend_from_slice(&0.002f64.to_le_bytes()); // no queue_s!
        let back = TraceStore::from_binary(&bin).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back.events()[0].version, 3);
        assert_eq!(back.events()[0].queue_s, 0.0);
        assert!(back.to_binary().starts_with(BINARY_MAGIC));
    }
}
