//! Replay: turn a recorded [`TraceStore`] back into a delay substrate
//! and run the whole scheme × policy matrix against it, offline and
//! bit-reproducibly — the "does the policy win on *this* fleet?" leg.
//!
//! Four replay sources:
//!
//! * [`ReplaySource::Empirical`] — bootstrap-resample the measured
//!   per-worker delays through [`crate::delay::EmpiricalModel`]
//!   (distribution-free; the default);
//! * [`ReplaySource::FittedTg`] — the fitted per-worker truncated
//!   Gaussians (paper eq. 66, smooth tails within the observed
//!   support);
//! * [`ReplaySource::FittedExp`] — the fitted per-worker shifted
//!   exponentials (heavier tail extrapolation beyond the observed
//!   maximum);
//! * [`ReplaySource::Corr`] — the truncated Gaussians wrapped in the
//!   fitted per-round worker-correlated slowdown
//!   ([`super::fit::FleetFit::correlated_model`]): same marginals,
//!   plus the measured round-to-round burstiness the independent
//!   sources smooth away.
//!
//! Every `(scheme, policy)` cell runs through
//! [`crate::adaptive::run_policy_rounds`] with the same seed, so all
//! cells share one delay stream (variance-reduced comparisons), and
//! the whole matrix folds into an FNV-1a **completion digest** over
//! the bit patterns of every per-round completion time — the
//! determinism pin of `rust/tests/trace.rs`: same trace + same config
//! ⇒ same digest, bit for bit.

use anyhow::{bail, Result};

use crate::adaptive::{run_policy_rounds, PerRound, PolicyKind, PolicyRunConfig};
use crate::coded::{DecodeCache, DecodeCacheStats, PcScheme, PcmmScheme};
use crate::delay::{DelayModel, EmpiricalModel, Trace};
use crate::scheme::{SchemeId, SchemeRegistry};
use crate::sim::CompletionEstimate;
use crate::util::fnv::Fnv1a;
use crate::util::rng::Rng;

use super::fit::fit_traces;
use super::record::TraceStore;

/// Which delay substrate a replay runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplaySource {
    /// Bootstrap resampling of the raw measured delays (default).
    Empirical,
    /// Fitted per-worker truncated Gaussians (eq. 66).
    FittedTg,
    /// Fitted per-worker shifted exponentials.
    FittedExp,
    /// Truncated Gaussians under the fitted per-round correlated
    /// slowdown (σ̂ at the fleet mean).
    Corr,
}

impl ReplaySource {
    /// CLI spelling: `empirical | tg | exp | corr`.
    pub fn parse(name: &str) -> Result<Self> {
        Ok(match name.trim().to_lowercase().as_str() {
            "empirical" => ReplaySource::Empirical,
            "tg" | "trunc-gauss" | "truncated-gaussian" => ReplaySource::FittedTg,
            "exp" | "shifted-exp" => ReplaySource::FittedExp,
            "corr" | "correlated" => ReplaySource::Corr,
            other => bail!("unknown replay source {other:?} (empirical|tg|exp|corr)"),
        })
    }
}

impl std::fmt::Display for ReplaySource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ReplaySource::Empirical => "empirical",
            ReplaySource::FittedTg => "tg",
            ReplaySource::FittedExp => "exp",
            ReplaySource::Corr => "corr",
        })
    }
}

/// Build the bootstrap-resampling model from a trace's raw delays.
pub fn empirical_model(store: &TraceStore) -> Result<EmpiricalModel> {
    if store.n_workers() == 0 {
        bail!("cannot replay an empty trace");
    }
    // one pass over the events, not one per worker per channel
    let (comp_all, comm_all) = store.per_worker_ms();
    let mut comp = Vec::with_capacity(comp_all.len());
    let mut comm = Vec::with_capacity(comm_all.len());
    for (w, (c, m)) in comp_all.into_iter().zip(comm_all).enumerate() {
        if c.is_empty() || m.is_empty() {
            bail!("worker {w} has no recorded delays — cannot bootstrap-replay it");
        }
        comp.push(Trace::new(c));
        comm.push(Trace::new(m));
    }
    Ok(EmpiricalModel::new(comp, comm))
}

/// Materialize the replay substrate for a source.
pub fn model_from_trace(store: &TraceStore, source: ReplaySource) -> Result<Box<dyn DelayModel>> {
    Ok(match source {
        ReplaySource::Empirical => Box::new(empirical_model(store)?),
        ReplaySource::FittedTg => Box::new(fit_traces(store)?.truncated_gaussian_model()),
        ReplaySource::FittedExp => Box::new(fit_traces(store)?.shifted_exp_model()),
        ReplaySource::Corr => Box::new(fit_traces(store)?.correlated_model()),
    })
}

/// The default replay matrix at an `(n, r, k)` point: every registered
/// scheme family that paper Table I admits there, in figure order.
pub fn default_matrix_schemes(n: usize, r: usize, k: usize) -> Vec<SchemeId> {
    let s = 2u32.min(r as u32).max(1);
    let candidates = [
        SchemeId::Cs,
        SchemeId::Ss,
        SchemeId::Ra,
        SchemeId::Gc(s),
        SchemeId::GcHet(s, 1),
        SchemeId::Pc,
        SchemeId::Pcmm,
        SchemeId::Lb,
    ];
    candidates
        .into_iter()
        .filter(|&id| SchemeRegistry::applicable(id, n, r, k))
        .collect()
}

/// One replay run's shape.
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    pub schemes: Vec<SchemeId>,
    pub policies: Vec<PolicyKind>,
    pub r: usize,
    pub k: usize,
    pub trials: usize,
    pub seed: u64,
    pub ingest_ms: f64,
    pub source: ReplaySource,
}

impl ReplayConfig {
    /// The full-matrix default at `r = k = n`: every scheme is
    /// applicable there, so the fleet question is answered in one run.
    pub fn matrix(n: usize, trials: usize, seed: u64) -> Self {
        Self {
            schemes: default_matrix_schemes(n, n, n),
            policies: vec![
                PolicyKind::Static,
                PolicyKind::AdaptiveOrder,
                PolicyKind::AdaptiveLoad,
            ],
            r: n,
            k: n,
            trials,
            seed,
            ingest_ms: 0.0,
            source: ReplaySource::Empirical,
        }
    }
}

/// One `(scheme, policy)` cell of the replay matrix.
#[derive(Debug, Clone)]
pub struct ReplayCell {
    pub scheme: SchemeId,
    pub policy: PolicyKind,
    pub estimate: CompletionEstimate,
    pub replans: usize,
}

/// Decode-weight cache behaviour of one coded scheme under this
/// trace's delays: per-round responder subsets are drawn from the
/// replay substrate (the scheme's own completion rule picks them) and
/// driven through a real [`DecodeCache`] — the measured answer to "do
/// this fleet's straggler patterns actually repeat?".
#[derive(Debug, Clone)]
pub struct DecodeCacheReplay {
    pub scheme: SchemeId,
    /// rounds simulated (one decode per round)
    pub rounds: usize,
    pub stats: DecodeCacheStats,
}

/// A replayed matrix plus its determinism pin.
#[derive(Debug, Clone)]
pub struct ReplayOutcome {
    pub cells: Vec<ReplayCell>,
    /// `(scheme, policy, reason)` pairs the matrix skipped — a policy
    /// that cannot re-plan a scheme's base is a gap in the table, not
    /// an error.
    pub skipped: Vec<(SchemeId, PolicyKind, String)>,
    /// FNV-1a fold of every per-round completion time's bit pattern,
    /// in run order — same trace + same config ⇒ same digest.
    /// Deliberately excludes the decode-cache leg, so the pin predates
    /// and survives it.
    pub digest: u64,
    pub model_name: String,
    /// one entry per applicable coded scheme in the config (empty when
    /// the matrix has no PC/PCMM)
    pub decode_cache: Vec<DecodeCacheReplay>,
}

/// Measure decode-weight cache behaviour for every coded scheme in the
/// config against `model`'s delay stream: each round samples a delay
/// realization, lets the scheme's own completion rule pick the
/// threshold-fastest responders, canonicalizes that subset and drives a
/// real [`DecodeCache`].  Runs on its own deterministic RNG stream
/// derived from the config seed, so it neither perturbs nor joins the
/// matrix completion digest.
fn decode_cache_replay(model: &dyn DelayModel, cfg: &ReplayConfig, n: usize) -> Vec<DecodeCacheReplay> {
    let mut out = Vec::new();
    // (arrival, id) pairs — reused across rounds and schemes
    let mut arrivals: Vec<(f64, usize)> = Vec::new();
    for &scheme in &cfg.schemes {
        if !matches!(scheme, SchemeId::Pc | SchemeId::Pcmm) {
            continue;
        }
        if !SchemeRegistry::applicable(scheme, n, cfg.r, cfg.k) {
            continue;
        }
        // per-scheme stream: the subsets a scheme sees do not depend on
        // which other schemes share the matrix
        let tag = if scheme == SchemeId::Pc { 1u64 } else { 2u64 };
        let mut rng = Rng::seed_from_u64(cfg.seed ^ 0xDEC0DE_u64.rotate_left(17) ^ tag);
        let mut cache = DecodeCache::with_default_cap();
        match scheme {
            SchemeId::Pc => {
                let pc = PcScheme::new(n, cfg.r);
                let m = pc.recovery_threshold();
                for _ in 0..cfg.trials {
                    let sample = model.sample(n, cfg.r, &mut rng);
                    arrivals.clear();
                    for i in 0..n {
                        // same finish rule as PcScheme::completion_time
                        let comp: f64 = sample.comp_row(i).iter().sum();
                        arrivals.push((comp + sample.comm(i, cfg.r - 1), i));
                    }
                    arrivals.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
                    let mut key: Vec<usize> = arrivals[..m].iter().map(|&(_, i)| i).collect();
                    key.sort_unstable();
                    cache.weights_for(&key, || pc.decode_weights(&key));
                }
            }
            SchemeId::Pcmm => {
                let pcmm = PcmmScheme::new(n, cfg.r);
                let m = pcmm.recovery_threshold();
                for _ in 0..cfg.trials {
                    let sample = model.sample(n, cfg.r, &mut rng);
                    arrivals.clear();
                    for i in 0..n {
                        // same slot-arrival rule as PcmmScheme::completion_time
                        let comp = sample.comp_row(i);
                        let mut prefix = 0.0;
                        for j in 0..cfg.r {
                            prefix += comp[j];
                            arrivals.push((prefix + sample.comm(i, j), i * cfg.r + j));
                        }
                    }
                    arrivals.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
                    let mut key: Vec<usize> = arrivals[..m].iter().map(|&(_, s)| s).collect();
                    key.sort_unstable();
                    cache.weights_for(&key, || pcmm.decode_weights(&key));
                }
            }
            _ => unreachable!("filtered above"),
        }
        out.push(DecodeCacheReplay {
            scheme,
            rounds: cfg.trials,
            stats: cache.stats(),
        });
    }
    out
}

/// Run the scheme × policy matrix against a trace's delays.
pub fn replay(store: &TraceStore, cfg: &ReplayConfig) -> Result<ReplayOutcome> {
    let n = store.n_workers();
    if cfg.schemes.is_empty() {
        bail!("replay needs at least one scheme");
    }
    if cfg.policies.is_empty() {
        bail!("replay needs at least one policy");
    }
    let model = model_from_trace(store, cfg.source)?;
    let round_model = PerRound(model.as_ref());

    let mut digest = Fnv1a::new();

    let mut cells = Vec::new();
    let mut skipped = Vec::new();
    for &scheme in &cfg.schemes {
        if !SchemeRegistry::applicable(scheme, n, cfg.r, cfg.k) {
            // the whole scheme is out at this shape: every requested
            // policy's cell is a gap
            for &policy in &cfg.policies {
                skipped.push((
                    scheme,
                    policy,
                    format!("{scheme} not applicable at (n = {n}, r = {}, k = {})", cfg.r, cfg.k),
                ));
            }
            continue;
        }
        for &policy in &cfg.policies {
            if policy != PolicyKind::Static {
                if let Err(e) = policy.validate_base(scheme, n, cfg.r) {
                    skipped.push((scheme, policy, e.to_string()));
                    continue;
                }
            }
            for b in scheme.to_string().bytes().chain(policy.to_string().bytes()) {
                digest.fold(b as u64);
            }
            let mut emit = |_round: usize, t: f64| digest.fold(t.to_bits());
            let out = run_policy_rounds(
                &PolicyRunConfig {
                    scheme,
                    policy,
                    n,
                    r: cfg.r,
                    k: cfg.k,
                    rounds: cfg.trials,
                    ingest_ms: cfg.ingest_ms,
                    seed: cfg.seed,
                    // the replay matrix compares schemes on one shared
                    // synchronous delay stream; async what-ifs run
                    // through `sim --staleness` instead
                    staleness: 1,
                },
                &round_model,
                Some(&mut emit),
                None,
            )?;
            cells.push(ReplayCell {
                scheme,
                policy,
                estimate: out.estimate,
                replans: out.replans,
            });
        }
    }
    if cells.is_empty() {
        bail!("replay matrix is empty: no (scheme, policy) pair was runnable at this shape");
    }
    let decode_cache = decode_cache_replay(model.as_ref(), cfg, n);
    Ok(ReplayOutcome {
        cells,
        skipped,
        digest: digest.digest(),
        model_name: model.name(),
        decode_cache,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::record::TraceRecorder;
    use crate::util::rng::Rng;

    fn synthetic_store(n: usize) -> TraceStore {
        let mut rec = TraceRecorder::new("CS");
        let mut rng = Rng::seed_from_u64(9);
        for round in 0..80 {
            for w in 0..n {
                let comp = 0.1 + 0.05 * (w as f64) + 0.02 * rng.f64();
                let comm = 0.5 + 0.1 * rng.f64();
                rec.push_slot(round, w, 0, comp, comm, false, round as u32);
            }
        }
        rec.into_store()
    }

    #[test]
    fn source_spellings_roundtrip() {
        for (s, want) in [
            ("empirical", ReplaySource::Empirical),
            ("TG", ReplaySource::FittedTg),
            ("shifted-exp", ReplaySource::FittedExp),
            ("correlated", ReplaySource::Corr),
        ] {
            assert_eq!(ReplaySource::parse(s).unwrap(), want);
        }
        for src in [
            ReplaySource::Empirical,
            ReplaySource::FittedTg,
            ReplaySource::FittedExp,
            ReplaySource::Corr,
        ] {
            assert_eq!(ReplaySource::parse(&src.to_string()).unwrap(), src);
        }
        assert!(ReplaySource::parse("wat").is_err());
    }

    #[test]
    fn empirical_model_means_match_trace() {
        let store = synthetic_store(3);
        let m = empirical_model(&store).unwrap();
        // worker 2 is slower than worker 0 by construction
        assert!(m.mean_comp(2).unwrap() > m.mean_comp(0).unwrap());
        let direct = store.comp_ms(1);
        let want = direct.iter().sum::<f64>() / direct.len() as f64;
        assert!((m.mean_comp(1).unwrap() - want).abs() < 1e-12);
    }

    #[test]
    fn default_matrix_respects_table1() {
        let ids = default_matrix_schemes(6, 6, 6);
        assert!(ids.contains(&SchemeId::Ra), "r = n admits RA");
        assert!(ids.contains(&SchemeId::Pc) && ids.contains(&SchemeId::Pcmm));
        let ids = default_matrix_schemes(6, 3, 4);
        assert!(!ids.contains(&SchemeId::Ra), "r < n excludes RA");
        assert!(!ids.contains(&SchemeId::Pc), "k < n excludes the coded pair");
        assert!(ids.contains(&SchemeId::Gc(2)));
    }

    #[test]
    fn replay_matrix_is_deterministic_and_seed_sensitive() {
        let store = synthetic_store(4);
        let cfg = ReplayConfig {
            trials: 60,
            ..ReplayConfig::matrix(4, 60, 0xF1EE7)
        };
        let a = replay(&store, &cfg).unwrap();
        let b = replay(&store, &cfg).unwrap();
        assert_eq!(a.digest, b.digest, "same trace + config ⇒ same digest");
        for (x, y) in a.cells.iter().zip(&b.cells) {
            assert_eq!(x.estimate.mean.to_bits(), y.estimate.mean.to_bits());
        }
        let c = replay(
            &store,
            &ReplayConfig {
                seed: 0xF1EE8,
                ..cfg.clone()
            },
        )
        .unwrap();
        assert_ne!(a.digest, c.digest, "different seed ⇒ different digest");
        // static policy runs every scheme; the re-planning policies skip
        // the coded/randomized bases into `skipped`, not into errors
        assert!(a.cells.iter().any(|c| c.scheme == SchemeId::Pcmm
            && c.policy == PolicyKind::Static));
        assert!(a
            .skipped
            .iter()
            .any(|(s, p, _)| *s == SchemeId::Pc && *p == PolicyKind::AdaptiveOrder));
    }

    #[test]
    fn decode_cache_leg_measures_repeating_subsets() {
        let store = synthetic_store(4);
        let cfg = ReplayConfig::matrix(4, 60, 0xCAFE);
        let a = replay(&store, &cfg).unwrap();
        let schemes: Vec<_> = a.decode_cache.iter().map(|d| d.scheme).collect();
        assert!(schemes.contains(&SchemeId::Pc) && schemes.contains(&SchemeId::Pcmm));
        for d in &a.decode_cache {
            assert_eq!(d.rounds, 60);
            assert_eq!(d.stats.lookups(), 60, "{}: one decode per round", d.scheme);
            assert!(
                d.stats.hits > 0,
                "{}: straggler subsets must repeat across 60 rounds at n = 4",
                d.scheme
            );
        }
        // the leg runs on its own derived stream: deterministic, and it
        // never perturbs the matrix completion digest
        let b = replay(&store, &cfg).unwrap();
        assert_eq!(a.digest, b.digest);
        for (x, y) in a.decode_cache.iter().zip(&b.decode_cache) {
            assert_eq!(x.stats, y.stats, "{}", x.scheme);
        }
    }

    #[test]
    fn fitted_sources_replay_too() {
        let store = synthetic_store(3);
        for source in [
            ReplaySource::FittedTg,
            ReplaySource::FittedExp,
            ReplaySource::Corr,
        ] {
            let cfg = ReplayConfig {
                schemes: vec![SchemeId::Cs, SchemeId::Lb],
                policies: vec![PolicyKind::Static],
                source,
                trials: 40,
                ..ReplayConfig::matrix(3, 40, 1)
            };
            let out = replay(&store, &cfg).unwrap();
            assert_eq!(out.cells.len(), 2);
            for cell in &out.cells {
                assert!(cell.estimate.mean > 0.0, "{source}: {}", cell.scheme);
            }
        }
    }
}
