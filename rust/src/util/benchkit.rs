//! Micro-benchmark harness (offline substrate — DESIGN.md §5; criterion
//! is unavailable in this build, and the `[[bench]]` targets use
//! `harness = false` with this kit instead).
//!
//! Method: warmup, then adaptive batching until a target measurement
//! window is filled; reports mean / std-dev / min across batches plus
//! derived throughput.  Deterministic output layout so `cargo bench`
//! logs diff cleanly between optimization iterations (EXPERIMENTS.md
//! §Perf workflow).

use std::time::{Duration, Instant};

/// One benchmark's results, in nanoseconds per iteration.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub iters: u64,
}

impl BenchResult {
    pub fn per_second(&self) -> f64 {
        1e9 / self.mean_ns
    }

    pub fn report_line(&self) -> String {
        let (scaled, unit) = scale_ns(self.mean_ns);
        format!(
            "{:<44} {:>10.3} {}/iter  (±{:>5.1}%, min {:.3} {}, {:.2e} it/s)",
            self.name,
            scaled,
            unit,
            100.0 * self.std_ns / self.mean_ns.max(1e-12),
            scale_ns(self.min_ns).0,
            scale_ns(self.min_ns).1,
            self.per_second()
        )
    }
}

fn scale_ns(ns: f64) -> (f64, &'static str) {
    if ns < 1e3 {
        (ns, "ns")
    } else if ns < 1e6 {
        (ns / 1e3, "µs")
    } else if ns < 1e9 {
        (ns / 1e6, "ms")
    } else {
        (ns / 1e9, "s")
    }
}

/// Benchmark `f`, returning timing statistics.
///
/// `f` must do one logical iteration per call; use `std::hint::black_box`
/// on inputs/outputs to defeat const-folding.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_with_budget(name, Duration::from_millis(300), &mut f)
}

/// Benchmark with an explicit measurement budget.
pub fn bench_with_budget<F: FnMut()>(name: &str, budget: Duration, f: &mut F) -> BenchResult {
    // warmup + batch sizing: aim for ≥ 30 batches within the budget
    let t0 = Instant::now();
    f();
    let single = t0.elapsed().max(Duration::from_nanos(20));
    let batch =
        ((budget.as_secs_f64() / 30.0 / single.as_secs_f64()).ceil() as u64).clamp(1, 1 << 22);

    // warmup one batch
    for _ in 0..batch.min(1000) {
        f();
    }

    let mut samples_ns: Vec<f64> = Vec::new();
    let mut iters = 0u64;
    let start = Instant::now();
    while start.elapsed() < budget || samples_ns.len() < 5 {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples_ns.push(t.elapsed().as_nanos() as f64 / batch as f64);
        iters += batch;
        if samples_ns.len() >= 200 {
            break;
        }
    }
    let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
    let var = samples_ns
        .iter()
        .map(|s| (s - mean) * (s - mean))
        .sum::<f64>()
        / (samples_ns.len() - 1).max(1) as f64;
    let min = samples_ns.iter().cloned().fold(f64::INFINITY, f64::min);
    let result = BenchResult {
        name: name.to_string(),
        mean_ns: mean,
        std_ns: var.sqrt(),
        min_ns: min,
        iters,
    };
    println!("{}", result.report_line());
    result
}

/// Group header for bench output.
pub fn group(title: &str) {
    println!("\n=== {title} ===");
}

/// Write a machine-readable JSON report of bench results (the
/// `BENCH_<target>.json` files EXPERIMENTS.md §Perf tracks across PRs).
///
/// Schema: `{ "target": ..., "benchmarks": [ { name, mean_ns, std_ns,
/// min_ns, iters, per_second }, ... ] }` — key order fixed so reports
/// diff cleanly between optimization iterations.
pub fn write_json_report(
    path: impl AsRef<std::path::Path>,
    target: &str,
    results: &[BenchResult],
) -> std::io::Result<()> {
    use crate::util::json::Json;
    let benchmarks: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::Obj(vec![
                ("name".into(), Json::Str(r.name.clone())),
                ("mean_ns".into(), Json::Num(r.mean_ns)),
                ("std_ns".into(), Json::Num(r.std_ns)),
                ("min_ns".into(), Json::Num(r.min_ns)),
                ("iters".into(), Json::Num(r.iters as f64)),
                ("per_second".into(), Json::Num(r.per_second())),
            ])
        })
        .collect();
    let root = Json::Obj(vec![
        ("target".into(), Json::Str(target.to_string())),
        ("benchmarks".into(), Json::Arr(benchmarks)),
    ]);
    std::fs::write(path, root.to_string_pretty())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_a_known_busy_loop() {
        let r = bench_with_budget(
            "busy-50us",
            Duration::from_millis(60),
            &mut || {
                let t = Instant::now();
                while t.elapsed() < Duration::from_micros(50) {
                    std::hint::spin_loop();
                }
            },
        );
        assert!(r.mean_ns > 45_000.0, "mean {}", r.mean_ns);
        assert!(r.mean_ns < 250_000.0, "mean {}", r.mean_ns);
        assert!(r.iters >= 5);
    }

    #[test]
    fn json_report_roundtrips() {
        use crate::util::json::Json;
        let r = BenchResult {
            name: "kernel/x".into(),
            mean_ns: 120.5,
            std_ns: 3.0,
            min_ns: 110.0,
            iters: 5000,
        };
        let path = std::env::temp_dir().join(format!("benchkit-test-{}.json", std::process::id()));
        write_json_report(&path, "hot_paths", &[r]).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("target").unwrap().as_str().unwrap(), "hot_paths");
        let benches = match parsed.get("benchmarks") {
            Some(Json::Arr(a)) => a,
            other => panic!("benchmarks not an array: {other:?}"),
        };
        assert_eq!(benches.len(), 1);
        assert_eq!(benches[0].get("name").unwrap().as_str().unwrap(), "kernel/x");
        assert_eq!(benches[0].get("mean_ns").unwrap().as_f64().unwrap(), 120.5);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn report_line_scales_units() {
        let r = BenchResult {
            name: "x".into(),
            mean_ns: 2_500_000.0,
            std_ns: 10_000.0,
            min_ns: 2_400_000.0,
            iters: 100,
        };
        assert!(r.report_line().contains("ms/iter"));
        assert!((r.per_second() - 400.0).abs() < 1.0);
    }
}
