//! Tiny command-line parser (offline substrate — DESIGN.md §5).
//!
//! Grammar: `prog <subcommand> [<action>] [--key value]... [--flag]...`
//! — at most two leading positionals (`trace record` style); further
//! positionals are rejected.  Typed getters with defaults; unknown
//! keys are collected so the binary can reject typos instead of
//! silently ignoring them.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    /// Second positional (`straggler trace record` → `record`);
    /// subcommands that take no action must reject `Some`.
    pub action: Option<String>,
    values: HashMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of argument strings (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = Some(it.next().unwrap());
                if let Some(second) = it.peek() {
                    if !second.starts_with('-') {
                        out.action = Some(it.next().unwrap());
                    }
                }
            }
        }
        while let Some(arg) = it.next() {
            let Some(key) = arg.strip_prefix("--") else {
                bail!("unexpected positional argument {arg:?}");
            };
            if key.is_empty() {
                bail!("bare `--` is not supported");
            }
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    let v = it.next().unwrap();
                    out.values.insert(key.to_string(), v);
                }
                _ => out.flags.push(key.to_string()),
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
    }

    pub fn str_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.values.get(key).cloned()
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or_else(|| default.to_string())
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// Like [`Self::usize_or`] but range-checked: the value must parse
    /// AND land in `[lo, hi]`.  The error spells the accepted range, so
    /// axis flags with hard bounds (`--staleness` ∈ [1, 8], like the
    /// `order@pQQ` percentile grammar) fail with actionable guidance at
    /// the flag instead of a deep validation error later.
    pub fn usize_in(&self, key: &str, default: usize, lo: usize, hi: usize) -> Result<usize> {
        let v = self.usize_or(key, default)?;
        if !(lo..=hi).contains(&v) {
            bail!("--{key} expects an integer in [{lo}, {hi}], got {v}");
        }
        Ok(v)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    /// Keys provided by the user but never consumed by a getter — call
    /// after all getters to catch typos.
    pub fn unknown_keys(&self) -> Vec<String> {
        let consumed = self.consumed.borrow();
        self.values
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !consumed.contains(k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_values() {
        let a = parse(&["fig4", "--trials", "500", "--scenario", "2", "--cluster"]);
        assert_eq!(a.subcommand.as_deref(), Some("fig4"));
        assert_eq!(a.usize_or("trials", 1).unwrap(), 500);
        assert_eq!(a.usize_or("scenario", 1).unwrap(), 2);
        assert!(a.flag("cluster"));
        assert!(!a.flag("missing"));
        assert!(a.unknown_keys().is_empty());
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["sim"]);
        assert_eq!(a.usize_or("n", 16).unwrap(), 16);
        assert_eq!(a.f64_or("eta", 0.01).unwrap(), 0.01);
        assert_eq!(a.str_or("model", "scenario1"), "scenario1");
    }

    #[test]
    fn negative_numbers_are_values() {
        let a = parse(&["x", "--shift", "-0.5"]);
        assert_eq!(a.f64_or("shift", 0.0).unwrap(), -0.5);
    }

    #[test]
    fn unknown_keys_detected() {
        let a = parse(&["fig4", "--trils", "5"]);
        let _ = a.usize_or("trials", 1);
        assert_eq!(a.unknown_keys(), vec!["trils".to_string()]);
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["x", "--n", "lots"]);
        assert!(a.usize_or("n", 1).is_err());
    }

    #[test]
    fn range_checked_getter_guides_the_user() {
        let a = parse(&["sim", "--staleness", "9"]);
        let err = a.usize_in("staleness", 1, 1, 8).unwrap_err().to_string();
        assert!(err.contains("[1, 8]"), "range must be spelled out: {err}");
        let a = parse(&["sim", "--staleness", "3"]);
        assert_eq!(a.usize_in("staleness", 1, 1, 8).unwrap(), 3);
        let a = parse(&["sim"]);
        assert_eq!(a.usize_in("staleness", 1, 1, 8).unwrap(), 1, "default");
    }

    #[test]
    fn action_positional_is_captured() {
        let a = parse(&["trace", "record", "--out", "t.jsonl"]);
        assert_eq!(a.subcommand.as_deref(), Some("trace"));
        assert_eq!(a.action.as_deref(), Some("record"));
        assert_eq!(a.str_or("out", ""), "t.jsonl");
        // plain subcommands leave the action empty
        let a = parse(&["fig4", "--trials", "5"]);
        assert_eq!(a.action, None);
    }

    #[test]
    fn rejects_third_positional() {
        assert!(Args::parse(
            ["trace", "record", "oops"].map(String::from)
        )
        .is_err());
    }
}
