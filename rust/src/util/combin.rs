//! Combinatorics for the Theorem-1 inclusion–exclusion evaluator:
//! binomial coefficients and subset enumeration.

/// Binomial coefficient `C(n, k)` as f64 (exact for the n ≤ 40 range the
/// analytic evaluator uses; f64 keeps the alternating sums stable).
pub fn binomial_f64(n: u64, k: u64) -> f64 {
    if k > n {
        return 0.0;
    }
    let k = k.min(n - k);
    let mut acc = 1.0_f64;
    for i in 0..k {
        acc = acc * (n - i) as f64 / (i + 1) as f64;
    }
    acc.round()
}

/// Visit every subset of `{0..n}` of exactly `size` elements.
///
/// Gosper's-hack-free lexicographic enumeration on an index vector:
/// deterministic order, no allocation beyond the scratch vec.
pub fn subsets_of_size<F: FnMut(&[usize])>(n: usize, size: usize, mut visit: F) {
    if size > n {
        return;
    }
    if size == 0 {
        visit(&[]);
        return;
    }
    let mut idx: Vec<usize> = (0..size).collect();
    loop {
        visit(&idx);
        // advance to next combination in lexicographic order
        let mut i = size;
        loop {
            if i == 0 {
                return;
            }
            i -= 1;
            if idx[i] != i + n - size {
                break;
            }
            if i == 0 {
                return;
            }
        }
        idx[i] += 1;
        for j in i + 1..size {
            idx[j] = idx[j - 1] + 1;
        }
    }
}

/// Iterate subsets as bitmasks of fixed popcount via Gosper's hack.
/// Usable for n ≤ 63; the Theorem-1 evaluator caps n ≤ 20 anyway.
pub fn masks_of_popcount(n: usize, size: usize) -> MaskIter {
    assert!(n < 64, "mask enumeration supports n < 64");
    MaskIter {
        n,
        current: if size == 0 {
            Some(0)
        } else if size <= n {
            Some((1u64 << size) - 1)
        } else {
            None
        },
        size,
    }
}

pub struct MaskIter {
    n: usize,
    current: Option<u64>,
    size: usize,
}

impl Iterator for MaskIter {
    type Item = u64;

    fn next(&mut self) -> Option<u64> {
        let cur = self.current?;
        // compute successor via Gosper's hack
        self.current = if self.size == 0 {
            None
        } else {
            let c = cur & cur.wrapping_neg();
            let r = cur + c;
            let next = (((r ^ cur) >> 2) / c) | r;
            if next < (1u64 << self.n) {
                Some(next)
            } else {
                None
            }
        };
        Some(cur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binomials() {
        assert_eq!(binomial_f64(0, 0), 1.0);
        assert_eq!(binomial_f64(5, 2), 10.0);
        assert_eq!(binomial_f64(10, 5), 252.0);
        assert_eq!(binomial_f64(16, 8), 12870.0);
        assert_eq!(binomial_f64(3, 5), 0.0);
        assert_eq!(binomial_f64(20, 0), 1.0);
    }

    #[test]
    fn pascal_identity() {
        for n in 1..25u64 {
            for k in 1..n {
                let lhs = binomial_f64(n, k);
                let rhs = binomial_f64(n - 1, k - 1) + binomial_f64(n - 1, k);
                assert_eq!(lhs, rhs, "C({n},{k})");
            }
        }
    }

    #[test]
    fn subset_counts_match_binomial() {
        for n in 0..10 {
            for s in 0..=n {
                let mut count = 0u64;
                subsets_of_size(n, s, |_| count += 1);
                assert_eq!(count as f64, binomial_f64(n as u64, s as u64), "n={n} s={s}");
            }
        }
    }

    #[test]
    fn subsets_are_sorted_and_distinct() {
        let mut seen = Vec::new();
        subsets_of_size(6, 3, |s| {
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            seen.push(s.to_vec());
        });
        let mut dedup = seen.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(seen.len(), dedup.len());
        assert_eq!(seen.len(), 20);
    }

    #[test]
    fn mask_iter_matches_subset_iter() {
        for n in 0..12 {
            for s in 0..=n {
                let masks: Vec<u64> = masks_of_popcount(n, s).collect();
                assert_eq!(
                    masks.len() as f64,
                    binomial_f64(n as u64, s as u64),
                    "n={n} s={s}"
                );
                for m in &masks {
                    assert_eq!(m.count_ones() as usize, s);
                    assert!(*m < (1u64 << n.max(1)));
                }
            }
        }
    }
}
