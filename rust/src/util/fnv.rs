//! FNV-1a folding — the crate's one determinism-pin hash.
//!
//! Both digest surfaces — the adaptive engine's decision digest
//! ([`crate::adaptive::PolicyEngine::decision_digest`]) and the trace
//! subsystem's replay completion digest
//! ([`crate::trace::ReplayOutcome::digest`]) — fold through this one
//! primitive, so "same inputs ⇒ same digest" can never diverge between
//! them by one side tweaking constants or fold order.

/// Incremental FNV-1a over `u64` words (each word folded xor-then-mul).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fnv1a(u64);

impl Fnv1a {
    /// The FNV-1a offset basis.
    pub const OFFSET: u64 = 0xcbf29ce484222325;
    /// The FNV-1a 64-bit prime.
    pub const PRIME: u64 = 0x100000001b3;

    pub fn new() -> Self {
        Self(Self::OFFSET)
    }

    /// Fold one word in.
    #[inline]
    pub fn fold(&mut self, v: u64) {
        self.0 ^= v;
        self.0 = self.0.wrapping_mul(Self::PRIME);
    }

    /// Current digest value.
    pub fn digest(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_the_reference_fold() {
        // the exact xor-then-mul sequence both digest surfaces relied
        // on before extraction — must never change
        let mut h = Fnv1a::new();
        for v in [3u64, 0x5A5A, u64::MAX] {
            h.fold(v);
        }
        let mut want = 0xcbf29ce484222325u64;
        for v in [3u64, 0x5A5A, u64::MAX] {
            want ^= v;
            want = want.wrapping_mul(0x100000001b3);
        }
        assert_eq!(h.digest(), want);
        assert_ne!(h.digest(), Fnv1a::new().digest());
    }

    #[test]
    fn order_sensitive() {
        let mut a = Fnv1a::new();
        a.fold(1);
        a.fold(2);
        let mut b = Fnv1a::new();
        b.fold(2);
        b.fold(1);
        assert_ne!(a.digest(), b.digest());
    }
}
