//! Minimal JSON parser/emitter (offline substrate — see DESIGN.md §5).
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes
//! and `\uXXXX`, numbers, booleans, null).  Used to read the AOT
//! `artifacts/manifest.json` written by python and to write experiment
//! configs/results.  Object key order is preserved (Vec-backed) so
//! emitted files diff cleanly.

use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// insertion-ordered object
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    // ---- construction helpers --------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- emission ----------------------------------------------------------

    /// Compact single-line rendering.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty rendering with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    item.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push(']');
            }
            Json::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * level {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(pairs)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("lone low surrogate"));
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("raw control char in string")),
                Some(b) => {
                    // re-assemble UTF-8 multibyte sequences
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let len = utf8_len(b).ok_or_else(|| self.err("bad utf-8"))?;
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump().ok_or_else(|| self.err("truncated utf-8"))?;
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf-8"))?;
                        s.push_str(chunk);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let original = Json::Str("line1\nta\tb \"q\" \\ ünïcode ✓".into());
        let text = original.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_escape_sequences() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
        // surrogate pair: U+1F600
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "\"unterminated", "01x", "[1] extra",
            r#""\ud83d""#,
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<&str> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn pretty_print_roundtrips() {
        let v = Json::obj(vec![
            ("name", Json::Str("fig4".into())),
            ("rs", Json::arr_usize(&[2, 4, 8, 16])),
            ("means", Json::arr_f64(&[0.86, 0.71, 0.692])),
            ("ok", Json::Bool(true)),
        ]);
        let text = v.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), v);
        assert!(text.contains("\n  \"rs\": ["));
    }

    #[test]
    fn integers_render_without_point() {
        assert_eq!(Json::Num(5.0).to_string_compact(), "5");
        assert_eq!(Json::Num(5.25).to_string_compact(), "5.25");
        assert_eq!(Json::Num(-0.0).to_string_compact(), "0");
    }

    #[test]
    fn real_manifest_shape_parses() {
        let text = r#"{
          "format": "hlo-text/v1",
          "artifacts": {
            "quickstart/task_gram": {
              "file": "quickstart__task_gram.hlo.txt",
              "arg_shapes": [[64, 32], [64]],
              "dims": {"d": 64, "b": 32, "n": 4, "m": 8}
            }
          }
        }"#;
        let v = Json::parse(text).unwrap();
        let art = v.get("artifacts").unwrap().get("quickstart/task_gram").unwrap();
        let shapes = art.get("arg_shapes").unwrap().as_arr().unwrap();
        assert_eq!(shapes[0].as_arr().unwrap()[0].as_usize(), Some(64));
        assert_eq!(
            art.get("dims").unwrap().get("d").unwrap().as_usize(),
            Some(64)
        );
    }
}
