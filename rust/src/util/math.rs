//! Special functions needed by the delay substrate and the analytic
//! completion-time evaluator.
//!
//! Self-contained (no external libm): `erf` combines the Maclaurin
//! series (small arguments) with the Legendre continued fraction for
//! `erfc` (large arguments, evaluated by modified Lentz), giving
//! ~1e-13 absolute accuracy everywhere — ample for truncated-Gaussian
//! inverse-CDF sampling and the analytic evaluator's quadrature.

use std::f64::consts::{FRAC_1_SQRT_2, PI};

/// Error function.  Series for |x| ≤ 2.5, `1 − erfc(x)` beyond.
pub fn erf(x: f64) -> f64 {
    if x.abs() <= 2.5 {
        erf_series(x)
    } else if x > 0.0 {
        1.0 - erfc_cf(x)
    } else {
        erfc_cf(-x) - 1.0
    }
}

/// Complementary error function.
pub fn erfc(x: f64) -> f64 {
    if x >= 2.5 {
        erfc_cf(x)
    } else if x <= -2.5 {
        2.0 - erfc_cf(-x)
    } else {
        1.0 - erf_series(x)
    }
}

/// Maclaurin series: erf(x) = 2/√π Σ (−1)ⁿ x^{2n+1} / (n! (2n+1)).
///
/// At |x| ≤ 2.5 the largest term is ≈ 80, so cancellation costs ≤ 2
/// digits — the result is still accurate to ~1e-14 absolute.
fn erf_series(x: f64) -> f64 {
    let x2 = x * x;
    let mut term = x; // n = 0 term before the 2/√π factor: x
    let mut sum = x;
    for n in 1..200 {
        let nf = n as f64;
        // term_n = term_{n-1} · (−x²/n), then weighted by (2n−1)/(2n+1)
        term *= -x2 / nf;
        let contrib = term / (2.0 * nf + 1.0);
        sum += contrib;
        if contrib.abs() < 1e-18 * sum.abs().max(1e-30) {
            break;
        }
    }
    2.0 / PI.sqrt() * sum
}

/// Legendre continued fraction for erfc, valid (and fast) for x ≥ 2:
///
/// erfc(x) = e^{−x²}/√π · 1 / (x + ½/(x + 1/(x + 3⁄2/(x + 2/(x + …)))))
///
/// evaluated with the modified Lentz algorithm.
fn erfc_cf(x: f64) -> f64 {
    debug_assert!(x > 0.0);
    const TINY: f64 = 1e-300;
    let mut f = x.max(TINY);
    let mut c = f;
    let mut d = 0.0_f64;
    for m in 1..300 {
        let a = m as f64 / 2.0; // the aₘ coefficients: 1/2, 1, 3/2, …
        // CF step: denominator b = x (every level)
        d = x + a * d;
        if d.abs() < TINY {
            d = TINY;
        }
        c = x + a / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        let delta = c * d;
        f *= delta;
        if (delta - 1.0).abs() < 1e-17 {
            break;
        }
    }
    (-x * x).exp() / (PI.sqrt() * f)
}

/// Standard normal PDF φ(x) (paper eq. 66b).
pub fn normal_pdf(x: f64) -> f64 {
    (-(x * x) / 2.0).exp() / (2.0 * PI).sqrt()
}

/// Standard normal CDF Φ(x) (paper eq. 66c).
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * erfc(-x * FRAC_1_SQRT_2)
}

/// Inverse error function: `erf(erf_inv(p)) = p` for `p ∈ (-1, 1)`.
pub fn erf_inv(p: f64) -> f64 {
    assert!(
        (-1.0..=1.0).contains(&p),
        "erf_inv domain is [-1, 1], got {p}"
    );
    if p == 1.0 {
        return f64::INFINITY;
    }
    if p == -1.0 {
        return f64::NEG_INFINITY;
    }
    // erf_inv(p) = Φ⁻¹((p+1)/2) / √2
    let mut y = normal_quantile((p + 1.0) / 2.0) * FRAC_1_SQRT_2;
    // Newton refinement on f(y) = erf(y) − p;  f'(y) = 2/√π e^{−y²}
    for _ in 0..2 {
        let e = erf(y) - p;
        let d = 2.0 / PI.sqrt() * (-y * y).exp();
        if d == 0.0 {
            break;
        }
        y -= e / d;
    }
    y
}

/// Standard normal quantile Φ⁻¹(p): Acklam's rational approximation
/// (relative error < 1.15e-9) plus one Halley step for ~1e-15.
pub fn normal_quantile(p: f64) -> f64 {
    let x = normal_quantile_fast(p);
    if !x.is_finite() {
        return x;
    }
    // one Halley step against the true CDF
    let e = normal_cdf(x) - p;
    let u = e * (2.0 * PI).sqrt() * (x * x / 2.0).exp();
    x - u / (1.0 + x * u / 2.0)
}

/// Acklam's approximation alone (relative error < 1.15e-9, no
/// refinement): ~4× cheaper, the Monte-Carlo sampling path.
pub fn normal_quantile_fast(p: f64) -> f64 {
    assert!((0.0..=1.0).contains(&p), "quantile domain is [0,1], got {p}");
    if p == 0.0 {
        return f64::NEG_INFINITY;
    }
    if p == 1.0 {
        return f64::INFINITY;
    }
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    let x = if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };
    x
}

/// Adaptive Simpson quadrature of `f` on `[a, b]` to absolute tolerance.
pub fn adaptive_simpson<F: Fn(f64) -> f64>(f: &F, a: f64, b: f64, tol: f64) -> f64 {
    fn simpson<F: Fn(f64) -> f64>(f: &F, a: f64, fa: f64, b: f64, fb: f64) -> (f64, f64, f64) {
        let m = 0.5 * (a + b);
        let fm = f(m);
        ((b - a) / 6.0 * (fa + 4.0 * fm + fb), m, fm)
    }
    #[allow(clippy::too_many_arguments)]
    fn rec<F: Fn(f64) -> f64>(
        f: &F,
        a: f64,
        fa: f64,
        b: f64,
        fb: f64,
        whole: f64,
        m: f64,
        fm: f64,
        tol: f64,
        depth: u32,
    ) -> f64 {
        let (left, lm, flm) = simpson(f, a, fa, m, fm);
        let (right, rm, frm) = simpson(f, m, fm, b, fb);
        let delta = left + right - whole;
        if depth == 0 || delta.abs() <= 15.0 * tol {
            left + right + delta / 15.0
        } else {
            rec(f, a, fa, m, fm, left, lm, flm, tol / 2.0, depth - 1)
                + rec(f, m, fm, b, fb, right, rm, frm, tol / 2.0, depth - 1)
        }
    }
    let fa = f(a);
    let fb = f(b);
    let (whole, m, fm) = simpson(f, a, fa, b, fb);
    rec(f, a, fa, b, fb, whole, m, fm, tol, 40)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_reference_values() {
        // reference values from scipy.special.erf
        let cases = [
            (0.0, 0.0),
            (0.1, 0.1124629160182849),
            (0.5, 0.5204998778130465),
            (1.0, 0.8427007929497149),
            (2.0, 0.9953222650189527),
            (3.0, 0.9999779095030014),
        ];
        for (x, want) in cases {
            assert!(
                (erf(x) - want).abs() < 1e-12,
                "erf({x}) = {} != {want}",
                erf(x)
            );
            assert!((erf(-x) + want).abs() < 1e-12, "erf odd symmetry at {x}");
        }
    }

    #[test]
    fn erfc_tail() {
        // reference values from glibc erfc (python math.erfc)
        assert!((erfc(4.5) / 1.9661604415428873e-10 - 1.0).abs() < 1e-10);
        assert!((erfc(3.0) / 2.2090496998585438e-05 - 1.0).abs() < 1e-10);
        assert!((erfc(10.0) / 2.088487583762545e-45 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn erf_erfc_consistency_across_crossover() {
        // the 2.5 switch point must be seamless
        for x in [2.49, 2.4999, 2.5, 2.5001, 2.51, -2.5, -2.49] {
            assert!((erf(x) + erfc(x) - 1.0).abs() < 1e-13, "at {x}");
        }
    }

    #[test]
    fn normal_cdf_symmetry_and_values() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-15);
        // scipy.stats.norm.cdf(1.96) = 0.9750021048517795
        assert!((normal_cdf(1.96) - 0.9750021048517795).abs() < 1e-12);
        for x in [-2.5, -1.0, 0.3, 2.2] {
            assert!((normal_cdf(x) + normal_cdf(-x) - 1.0).abs() < 1e-13);
        }
    }

    #[test]
    fn quantile_inverts_cdf() {
        for p in [1e-6, 0.01, 0.2, 0.5, 0.77, 0.99, 1.0 - 1e-6] {
            let x = normal_quantile(p);
            assert!(
                (normal_cdf(x) - p).abs() < 1e-12,
                "Φ(Φ⁻¹({p})) = {} off",
                normal_cdf(x)
            );
        }
    }

    #[test]
    fn erf_inv_inverts_erf() {
        for p in [-0.999, -0.5, -0.1, 0.0, 0.1, 0.5, 0.9, 0.999] {
            let y = erf_inv(p);
            assert!((erf(y) - p).abs() < 1e-12, "erf(erf_inv({p})) off: {}", erf(y));
        }
    }

    #[test]
    #[should_panic(expected = "erf_inv domain")]
    fn erf_inv_rejects_out_of_domain() {
        erf_inv(1.5);
    }

    #[test]
    fn simpson_integrates_polynomials_exactly() {
        // Simpson is exact for cubics
        let f = |x: f64| 3.0 * x * x * x - x + 2.0;
        let got = adaptive_simpson(&f, -1.0, 2.0, 1e-12);
        // ∫ = [3x⁴/4 − x²/2 + 2x] over [−1, 2] = 14 − (−1.75)
        let want = 14.0 - (-1.75);
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
    }

    #[test]
    fn simpson_gaussian_integral() {
        let got = adaptive_simpson(&normal_pdf, -8.0, 8.0, 1e-12);
        assert!((got - 1.0).abs() < 1e-10, "{got}");
    }
}
