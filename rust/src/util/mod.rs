//! Numerical utilities shared across the crate: special functions,
//! streaming statistics, and combinatorics.

pub mod benchkit;
pub mod cli;
pub mod combin;
pub mod fnv;
pub mod json;
pub mod math;
pub mod poll;
pub mod rng;
pub mod signal;
pub mod stats;

pub use combin::{binomial_f64, subsets_of_size};
pub use math::{erf, erf_inv, normal_cdf, normal_pdf, normal_quantile};
pub use stats::{quantile_sorted, RunningStats};
