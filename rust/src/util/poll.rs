//! Minimal raw-FFI wrapper over the OS `poll(2)` syscall.
//!
//! The offline build carries no `libc` crate (the only dependency is
//! the vendored `anyhow` shim), so the `pollfd` layout and event bits
//! are declared here directly.  Both are fixed by POSIX and identical
//! across the platforms this crate targets; the one genuine divergence
//! — the `nfds_t` width — is cfg-gated below.
//!
//! This is the readiness substrate of the master's event-driven data
//! plane ([`crate::coordinator::reactor`]): one `poll` call watches
//! every worker socket at once, replacing the thread-per-worker
//! blocking readers.  `poll` (not `epoll`) keeps the wrapper portable
//! and dependency-free; at the fleet sizes the coordinator runs
//! (n ≤ a few hundred sockets) the O(n) scan per wakeup is noise next
//! to one frame decode.

use std::io;
use std::os::unix::io::RawFd;

/// Readable data available (POSIX `POLLIN`).
pub const POLLIN: i16 = 0x001;
/// Writable without blocking (POSIX `POLLOUT`).
pub const POLLOUT: i16 = 0x004;
/// Error condition (output only — never requested).
pub const POLLERR: i16 = 0x008;
/// Peer hung up (output only).
pub const POLLHUP: i16 = 0x010;
/// Fd not open (output only).
pub const POLLNVAL: i16 = 0x020;

/// POSIX `struct pollfd`, byte-compatible with the C definition.
#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct PollFd {
    pub fd: RawFd,
    /// Requested events (`POLLIN | POLLOUT`).
    pub events: i16,
    /// Returned events, filled by the kernel.
    pub revents: i16,
}

impl PollFd {
    pub fn new(fd: RawFd, events: i16) -> Self {
        Self {
            fd,
            events,
            revents: 0,
        }
    }

    pub fn readable(&self) -> bool {
        self.revents & POLLIN != 0
    }

    pub fn writable(&self) -> bool {
        self.revents & POLLOUT != 0
    }

    /// Error/hangup/invalid — the connection is done for.
    pub fn failed(&self) -> bool {
        self.revents & (POLLERR | POLLHUP | POLLNVAL) != 0
    }
}

/// An auxiliary fd owner that wants to ride an existing `poll(2)` loop
/// — the mechanism by which the telemetry scrape listener joins the
/// reactor's poll set without a thread of its own.
///
/// Per poll iteration the loop calls [`PollHook::register`] to let the
/// hook append its fds (listener + in-flight connections) to the set,
/// then after `poll_fds` returns hands exactly that appended sub-slice
/// — same order, `revents` filled — to [`PollHook::service`].  The hook
/// must tolerate spurious wakeups (service with no ready fds) and must
/// never block: all its sockets are non-blocking and it does bounded
/// work per call, so the owning loop's latency is unaffected.
pub trait PollHook {
    /// Append this hook's fds (with their requested `events`) to `fds`.
    fn register(&mut self, fds: &mut Vec<PollFd>);
    /// Handle readiness on the fds appended by the matching
    /// `register` call; `fds` is that same sub-slice, `revents` filled.
    fn service(&mut self, fds: &[PollFd]);
}

// `nfds_t` is `unsigned long` on Linux and `unsigned int` on the BSDs
// (incl. macOS) — the only layout difference in the whole API.
#[cfg(any(target_os = "macos", target_os = "freebsd", target_os = "openbsd"))]
type NFds = u32;
#[cfg(not(any(target_os = "macos", target_os = "freebsd", target_os = "openbsd")))]
type NFds = core::ffi::c_ulong;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: NFds, timeout: i32) -> i32;
}

/// Poll `fds`, blocking up to `timeout_ms` (`0` = non-blocking probe,
/// negative = wait forever).  Returns the number of fds with non-zero
/// `revents`.  `EINTR` is retried transparently — callers that care
/// about the elapsed budget re-derive it from their own deadline.
pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
    if fds.is_empty() {
        return Ok(0);
    }
    loop {
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as NFds, timeout_ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = io::Error::last_os_error();
        if err.kind() != io::ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    fn pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        (client, server)
    }

    #[test]
    fn idle_socket_polls_not_readable() {
        let (client, _server) = pair();
        let mut fds = [PollFd::new(client.as_raw_fd(), POLLIN)];
        let n = poll_fds(&mut fds, 0).unwrap();
        assert_eq!(n, 0, "no data queued yet");
        assert!(!fds[0].readable());
    }

    #[test]
    fn written_socket_polls_readable() {
        let (mut client, server) = pair();
        client.write_all(b"ping").unwrap();
        client.flush().unwrap();
        let mut fds = [PollFd::new(server.as_raw_fd(), POLLIN)];
        // a localhost write is visible within any sane timeout
        let n = poll_fds(&mut fds, 2_000).unwrap();
        assert_eq!(n, 1);
        assert!(fds[0].readable());
        let mut buf = [0u8; 4];
        (&server).read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"ping");
    }

    #[test]
    fn fresh_socket_polls_writable() {
        let (client, _server) = pair();
        let mut fds = [PollFd::new(client.as_raw_fd(), POLLOUT)];
        let n = poll_fds(&mut fds, 2_000).unwrap();
        assert_eq!(n, 1);
        assert!(fds[0].writable());
    }

    #[test]
    fn hangup_is_reported() {
        let (client, server) = pair();
        drop(client);
        let mut fds = [PollFd::new(server.as_raw_fd(), POLLIN)];
        let n = poll_fds(&mut fds, 2_000).unwrap();
        assert_eq!(n, 1);
        // a closed peer reports POLLIN (EOF is readable) and/or POLLHUP
        assert!(fds[0].readable() || fds[0].failed());
    }

    #[test]
    fn empty_fd_set_is_a_noop() {
        assert_eq!(poll_fds(&mut [], 0).unwrap(), 0);
    }
}
