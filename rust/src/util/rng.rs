//! Deterministic pseudo-random generator for the Monte-Carlo engines.
//!
//! xoshiro256++ (Blackman & Vigna) seeded through SplitMix64 — the same
//! construction `rand`'s small-rng uses.  Implemented in-tree because
//! the build is fully offline (DESIGN.md §5): period 2²⁵⁶−1, passes
//! BigCrush, and — crucially for reproducible experiments — the stream
//! is a pure function of the `u64` seed, stable across platforms and
//! crate versions.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that low-entropy seeds (0, 1, 2, …) still
    /// produce well-mixed initial states.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Self { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)` (53-bit mantissa construction).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `(0, 1]` — safe for `ln()`.
    #[inline]
    pub fn f64_open_left(&mut self) -> f64 {
        1.0 - self.f64()
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift
    /// rejection (unbiased).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "below(0)");
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound {
                return (m >> 64) as usize;
            }
            // rejection zone check
            let t = bound.wrapping_neg() % bound;
            if lo >= t {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.below(i + 1);
            slice.swap(i, j);
        }
    }

    /// Standard normal via Box–Muller (cosine branch).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64_open_left().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (inverse CDF).
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64_open_left().max(1e-300).ln() / lambda
    }

    /// Derive an independent child stream (for thread sharding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::seed_from_u64(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::seed_from_u64(2);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.f64()).sum();
        assert!((sum / n as f64 - 0.5).abs() < 0.005);
    }

    #[test]
    fn below_is_unbiased_chi_square() {
        let mut r = Rng::seed_from_u64(3);
        let bound = 7;
        let trials = 70_000;
        let mut counts = vec![0u32; bound];
        for _ in 0..trials {
            counts[r.below(bound)] += 1;
        }
        let expected = trials as f64 / bound as f64;
        let chi2: f64 = counts
            .iter()
            .map(|&c| (c as f64 - expected).powi(2) / expected)
            .sum();
        // 6 dof, 99.9% critical value ≈ 22.5
        assert!(chi2 < 22.5, "chi2 = {chi2}: {counts:?}");
    }

    #[test]
    fn below_never_exceeds_bound() {
        let mut r = Rng::seed_from_u64(4);
        for bound in [1usize, 2, 3, 10, 1000] {
            for _ in 0..1000 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    #[should_panic(expected = "below(0)")]
    fn below_zero_panics() {
        Rng::seed_from_u64(0).below(0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "w.h.p. shuffled");
    }

    #[test]
    fn shuffle_uniform_first_element() {
        let mut r = Rng::seed_from_u64(6);
        let n = 5;
        let trials = 50_000;
        let mut counts = vec![0u32; n];
        for _ in 0..trials {
            let mut v: Vec<usize> = (0..n).collect();
            r.shuffle(&mut v);
            counts[v[0]] += 1;
        }
        let expected = trials as f64 / n as f64;
        for &c in &counts {
            assert!((c as f64 - expected).abs() < 5.0 * expected.sqrt());
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(7);
        let n = 200_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::seed_from_u64(8);
        let lambda = 4.0;
        let n = 200_000;
        let sum: f64 = (0..n).map(|_| r.exponential(lambda)).sum();
        assert!((sum / n as f64 - 0.25).abs() < 0.005);
    }

    #[test]
    fn fork_streams_decorrelated() {
        let mut parent = Rng::seed_from_u64(9);
        let mut a = parent.fork(0);
        let mut b = parent.fork(1);
        let matches = (0..1000).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(matches, 0);
    }
}
