//! Minimal raw-FFI SIGINT latch — the graceful-shutdown substrate.
//!
//! Same no-`libc` constraint as [`crate::util::poll`]: the offline
//! build vendors no FFI crate, so the one syscall wrapper this needs
//! (`signal(2)`) is declared here directly.  The handler does the only
//! async-signal-safe thing possible — it flips a process-wide
//! [`AtomicBool`] — and the master's round loops poll
//! [`interrupted`] at their top, so a Ctrl-C lands between rounds:
//! θ stays consistent, the telemetry log gets its final snapshot and
//! fsync ([`crate::telemetry::MetricsLog::finalize`]), and workers are
//! shut down over the wire instead of being orphaned.
//!
//! Installing is idempotent ([`std::sync::Once`]); the latch is
//! observe-only from the hot path (one relaxed load per round).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Once;

/// POSIX `SIGINT` — identical across the platforms this crate targets.
const SIGINT: i32 = 2;

static INTERRUPTED: AtomicBool = AtomicBool::new(false);
static INSTALL: Once = Once::new();

extern "C" fn on_sigint(_signum: i32) {
    // the only async-signal-safe action: flip the latch
    INTERRUPTED.store(true, Ordering::SeqCst);
}

extern "C" {
    /// `sighandler_t signal(int signum, sighandler_t handler)` — the
    /// return value (previous handler / `SIG_ERR`) is unused here.
    fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
}

/// Install the SIGINT latch (idempotent — later calls are no-ops).
/// After this, Ctrl-C no longer kills the process; callers must poll
/// [`interrupted`] and exit their loops cooperatively.
pub fn install_sigint_latch() {
    INSTALL.call_once(|| {
        unsafe { signal(SIGINT, on_sigint) };
    });
}

/// Has SIGINT arrived since the last [`clear_interrupt`]?
#[inline]
pub fn interrupted() -> bool {
    INTERRUPTED.load(Ordering::Relaxed)
}

/// Re-arm the latch (start of a fresh run; tests).
pub fn clear_interrupt() {
    INTERRUPTED.store(false, Ordering::SeqCst);
}

/// Trip the latch from code — what the signal handler does, minus the
/// signal.  Lets tests (and in-process embedders) exercise the graceful
/// path without delivering a real SIGINT to the whole test binary.
pub fn request_interrupt() {
    INTERRUPTED.store(true, Ordering::SeqCst);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latch_trips_and_clears() {
        clear_interrupt();
        assert!(!interrupted());
        request_interrupt();
        assert!(interrupted());
        // idempotent re-trip, then re-arm
        request_interrupt();
        assert!(interrupted());
        clear_interrupt();
        assert!(!interrupted());
    }

    #[test]
    fn install_is_idempotent() {
        install_sigint_latch();
        install_sigint_latch();
        // the latch itself still behaves after (re-)install
        clear_interrupt();
        assert!(!interrupted());
    }
}
