//! Streaming and batch statistics used by the Monte-Carlo engines and
//! the metrics layer.

/// Welford streaming accumulator: mean / variance / extrema in one pass,
/// numerically stable for the millions-of-rounds Monte-Carlo runs.
#[derive(Debug, Clone, Default)]
pub struct RunningStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl RunningStats {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold one observation in.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    /// Merge another accumulator (parallel reduction), Chan et al. form.
    pub fn merge(&mut self, other: &RunningStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let total = n1 + n2;
        self.mean += delta * n2 / total;
        self.m2 += other.m2 + delta * delta * n1 * n2 / total;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Unbiased sample variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean — the Monte-Carlo confidence handle.
    pub fn std_err(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            (self.variance() / self.n as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Linear-interpolated quantile of an **ascending-sorted** slice
/// (type-7 / numpy default).
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&q), "quantile level must be in [0,1]");
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_stats_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = RunningStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // sample variance of that classic set is 32/7
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn merge_equals_single_stream() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0 + 1.0).collect();
        let mut whole = RunningStats::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = RunningStats::new();
        let mut b = RunningStats::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.variance() - whole.variance()).abs() < 1e-10);
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut a = RunningStats::new();
        a.push(1.0);
        a.push(3.0);
        let before = (a.count(), a.mean());
        a.merge(&RunningStats::new());
        assert_eq!((a.count(), a.mean()), before);

        let mut e = RunningStats::new();
        e.merge(&a);
        assert_eq!(e.count(), 2);
        assert!((e.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_stats_are_nan() {
        let s = RunningStats::new();
        assert!(s.mean().is_nan());
        assert!(s.variance().is_nan());
        assert!(s.std_err().is_nan());
    }

    #[test]
    fn quantiles() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile_sorted(&v, 0.0), 1.0);
        assert_eq!(quantile_sorted(&v, 1.0), 4.0);
        assert!((quantile_sorted(&v, 0.5) - 2.5).abs() < 1e-12);
        assert!((quantile_sorted(&v, 1.0 / 3.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn quantile_empty_panics() {
        quantile_sorted(&[], 0.5);
    }
}
