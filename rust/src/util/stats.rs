//! Streaming and batch statistics used by the Monte-Carlo engines and
//! the metrics layer.

/// Welford streaming accumulator: mean / variance / extrema in one pass,
/// numerically stable for the millions-of-rounds Monte-Carlo runs.
#[derive(Debug, Clone, Default)]
pub struct RunningStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl RunningStats {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold one observation in.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    /// Merge another accumulator (parallel reduction), Chan et al. form.
    pub fn merge(&mut self, other: &RunningStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let total = n1 + n2;
        self.mean += delta * n2 / total;
        self.m2 += other.m2 + delta * delta * n1 * n2 / total;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Unbiased sample variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean — the Monte-Carlo confidence handle.
    pub fn std_err(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            (self.variance() / self.n as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exponentially-weighted moving average of a delay stream, with the
/// matching exponentially-weighted variance (West 1979 incremental
/// form) — the drift-tracking estimator of [`crate::adaptive`]: unlike
/// [`RunningStats`], old observations decay at rate `1 − α`, so a
/// worker whose service rate *changes* mid-run is re-estimated within
/// `O(1/α)` observations instead of being averaged against its past.
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    mean: f64,
    var: f64,
    count: u64,
}

impl Ewma {
    /// `alpha ∈ (0, 1]`: weight of the newest observation.
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "EWMA weight must be in (0, 1]");
        Self {
            alpha,
            mean: 0.0,
            var: 0.0,
            count: 0,
        }
    }

    /// Fold one observation in.  The first observation initializes the
    /// mean exactly (no bias toward zero).
    pub fn push(&mut self, x: f64) {
        self.count += 1;
        if self.count == 1 {
            self.mean = x;
            self.var = 0.0;
            return;
        }
        let delta = x - self.mean;
        self.mean += self.alpha * delta;
        self.var = (1.0 - self.alpha) * (self.var + self.alpha * delta * delta);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Current mean estimate; `NaN` before the first observation.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Current exponentially-weighted variance estimate.
    pub fn variance(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.var
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Streaming quantile estimator with a deterministic, mergeable state —
/// the memory-O(1) replacement for the Monte-Carlo engine's old
/// buffer-everything-then-sort quantiles.
///
/// Strategy (the "fixed-grid" estimator of EXPERIMENTS.md §Perf):
///
/// * up to [`StreamingQuantiles::EXACT_CAP`] observations are buffered
///   and quantiles are **exact** (sort + type-7 interpolation — covers
///   every small/medium experiment bit-for-bit);
/// * past the cap the buffer collapses into a fixed grid of
///   [`StreamingQuantiles::GRID_BINS`] bins spanning the range observed
///   *so far* plus 25 % margin; further values cost O(1) and quantiles
///   interpolate within a bin, so for quantiles that fall inside the
///   grid span the absolute error is around one bin width
///   (tolerance-tested in `rust/tests/batch_engine.rs`).  Values beyond
///   the frozen span clamp into the edge bins, so extreme quantiles of
///   heavy-tailed streams (far outside the first
///   [`StreamingQuantiles::EXACT_CAP`] observations' range) degrade to
///   "edge bin, clamped to the true observed min/max" — fine for the
///   engine's p50/p95 on unimodal completion times, not a
///   general-purpose tail estimator.
///
/// Merging (used for per-shard → global reduction) is deterministic for
/// a fixed merge order, which the engine guarantees by always folding
/// shards in shard-index order.
#[derive(Debug, Clone)]
pub struct StreamingQuantiles {
    count: u64,
    min: f64,
    max: f64,
    mode: QuantileMode,
}

#[derive(Debug, Clone)]
enum QuantileMode {
    Exact(Vec<f64>),
    Grid {
        lo: f64,
        width: f64,
        bins: Vec<u64>,
    },
}

impl Default for StreamingQuantiles {
    fn default() -> Self {
        Self::new()
    }
}

impl StreamingQuantiles {
    /// Observations kept exactly before degrading to the grid.
    pub const EXACT_CAP: usize = 4096;
    /// Grid resolution after degradation.
    pub const GRID_BINS: usize = 2048;

    pub fn new() -> Self {
        Self {
            count: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            mode: QuantileMode::Exact(Vec::new()),
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// True while quantiles are still exact order statistics.
    pub fn is_exact(&self) -> bool {
        matches!(self.mode, QuantileMode::Exact(_))
    }

    /// Fold one observation in.
    pub fn push(&mut self, x: f64) {
        self.count += 1;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
        match &mut self.mode {
            QuantileMode::Exact(buf) => {
                buf.push(x);
                if buf.len() > Self::EXACT_CAP {
                    self.degrade_to_grid();
                }
            }
            QuantileMode::Grid { lo, width, bins } => {
                let idx = grid_index(x, *lo, *width, bins.len());
                bins[idx] += 1;
            }
        }
    }

    /// Collapse the exact buffer into the fixed grid.
    fn degrade_to_grid(&mut self) {
        let buf = match &self.mode {
            QuantileMode::Exact(buf) => buf.clone(),
            QuantileMode::Grid { .. } => return,
        };
        // a degenerate (constant) stream still needs a nonzero bin
        // width; scale the floor to the data so it never underflows
        let mut span = self.max - self.min;
        if !(span > 0.0) {
            span = self.max.abs().max(1.0) * 1e-9;
        }
        let lo = self.min - 0.25 * span;
        let width = 1.5 * span / Self::GRID_BINS as f64;
        let mut bins = vec![0u64; Self::GRID_BINS];
        for &v in &buf {
            bins[grid_index(v, lo, width, Self::GRID_BINS)] += 1;
        }
        self.mode = QuantileMode::Grid { lo, width, bins };
    }

    /// Estimated `q`-quantile (`q ∈ [0, 1]`); exact while in buffer
    /// mode, about one grid-bin width of error afterwards for
    /// quantiles inside the grid span (see the type docs for the
    /// heavy-tail caveat), always clamped to the true observed
    /// `[min, max]`.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!(self.count > 0, "quantile of empty estimator");
        assert!((0.0..=1.0).contains(&q), "quantile level must be in [0,1]");
        match &self.mode {
            QuantileMode::Exact(buf) => {
                let mut sorted = buf.clone();
                sorted.sort_unstable_by(f64::total_cmp);
                quantile_sorted(&sorted, q)
            }
            QuantileMode::Grid { lo, width, bins } => {
                let target = q * (self.count - 1) as f64;
                let mut before = 0u64;
                for (i, &c) in bins.iter().enumerate() {
                    if c == 0 {
                        continue;
                    }
                    let last_rank = (before + c - 1) as f64;
                    if target <= last_rank {
                        // interpolate at mid-offsets within the bin
                        let p = (target - before as f64 + 0.5) / c as f64;
                        let v = lo + (i as f64 + p) * width;
                        return v.clamp(self.min, self.max);
                    }
                    before += c;
                }
                self.max
            }
        }
    }

    /// Several quantiles at once — in exact mode the buffer is cloned
    /// and sorted a single time instead of once per level (the
    /// `CompletionEstimate` path asks for p50 and p95 together).
    /// Bit-identical to calling [`StreamingQuantiles::quantile`] per
    /// level.
    pub fn quantiles(&self, levels: &[f64]) -> Vec<f64> {
        match &self.mode {
            QuantileMode::Exact(buf) => {
                assert!(self.count > 0, "quantile of empty estimator");
                let mut sorted = buf.clone();
                sorted.sort_unstable_by(f64::total_cmp);
                levels.iter().map(|&q| quantile_sorted(&sorted, q)).collect()
            }
            QuantileMode::Grid { .. } => levels.iter().map(|&q| self.quantile(q)).collect(),
        }
    }

    /// Allocation-reusing variant of [`StreamingQuantiles::quantiles`]
    /// for the telemetry snapshot path: results land in `out` (cleared
    /// first) and exact mode sorts into the caller's `scratch` instead
    /// of a fresh clone.  Once both vectors have warmed to capacity —
    /// and always in grid mode — the call is allocation-free, which is
    /// what lets [`crate::telemetry`] promise a zero-steady-state-
    /// allocation `snapshot`.  Values are bit-identical to
    /// [`StreamingQuantiles::quantiles`].
    pub fn quantiles_with(&self, levels: &[f64], out: &mut Vec<f64>, scratch: &mut Vec<f64>) {
        out.clear();
        match &self.mode {
            QuantileMode::Exact(buf) => {
                assert!(self.count > 0, "quantile of empty estimator");
                scratch.clear();
                scratch.extend_from_slice(buf);
                scratch.sort_unstable_by(f64::total_cmp);
                out.extend(levels.iter().map(|&q| quantile_sorted(scratch, q)));
            }
            QuantileMode::Grid { .. } => out.extend(levels.iter().map(|&q| self.quantile(q))),
        }
    }

    /// Merge another estimator (per-shard reduction).  Deterministic
    /// for a fixed merge order; the engine folds shards in index order.
    pub fn merge(&mut self, other: &StreamingQuantiles) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = other.clone();
            return;
        }
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.count += other.count;
        // fast path: both exact and still under the cap
        if let (QuantileMode::Exact(a), QuantileMode::Exact(b)) = (&mut self.mode, &other.mode) {
            if a.len() + b.len() <= Self::EXACT_CAP {
                a.extend_from_slice(b);
                return;
            }
        }
        if self.is_exact() {
            self.degrade_to_grid();
        }
        let (lo, width, bins) = match &mut self.mode {
            QuantileMode::Grid { lo, width, bins } => (*lo, *width, bins),
            QuantileMode::Exact(_) => unreachable!("degraded above"),
        };
        match &other.mode {
            QuantileMode::Exact(buf) => {
                for &v in buf {
                    bins[grid_index(v, lo, width, Self::GRID_BINS)] += 1;
                }
            }
            QuantileMode::Grid {
                lo: olo,
                width: owidth,
                bins: obins,
            } => {
                // fold the other grid's mass in at its bin centers
                for (i, &c) in obins.iter().enumerate() {
                    if c == 0 {
                        continue;
                    }
                    let center = olo + (i as f64 + 0.5) * owidth;
                    bins[grid_index(center, lo, width, Self::GRID_BINS)] += c;
                }
            }
        }
    }
}

#[inline]
fn grid_index(x: f64, lo: f64, width: f64, n_bins: usize) -> usize {
    let idx = ((x - lo) / width).floor();
    if idx < 0.0 {
        0
    } else if idx >= n_bins as f64 {
        n_bins - 1
    } else {
        idx as usize
    }
}

/// Linear-interpolated quantile of an **ascending-sorted** slice
/// (type-7 / numpy default).
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&q), "quantile level must be in [0,1]");
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_first_observation_initializes_exactly() {
        let mut e = Ewma::new(0.2);
        assert!(e.mean().is_nan());
        e.push(3.5);
        assert_eq!(e.mean(), 3.5);
        assert_eq!(e.variance(), 0.0);
    }

    #[test]
    fn ewma_converges_to_constant_stream() {
        let mut e = Ewma::new(0.2);
        for _ in 0..200 {
            e.push(2.0);
        }
        assert!((e.mean() - 2.0).abs() < 1e-12);
        assert!(e.variance() < 1e-12);
    }

    #[test]
    fn ewma_tracks_a_level_shift_within_1_over_alpha() {
        // the drift-tracking property the adaptive estimator relies on:
        // after a mean shift, ~3/α observations re-center the estimate
        let mut e = Ewma::new(0.2);
        for _ in 0..100 {
            e.push(1.0);
        }
        for _ in 0..15 {
            e.push(4.0);
        }
        assert!(e.mean() > 3.5, "mean {} should have re-centered", e.mean());
        let mut slow = RunningStats::new();
        for _ in 0..100 {
            slow.push(1.0);
        }
        for _ in 0..15 {
            slow.push(4.0);
        }
        assert!(
            slow.mean() < 1.5,
            "uniform average {} stays anchored — the contrast EWMA exists for",
            slow.mean()
        );
    }

    #[test]
    fn ewma_variance_reflects_spread() {
        let mut e = Ewma::new(0.1);
        for i in 0..2000 {
            e.push(if i % 2 == 0 { 0.0 } else { 2.0 });
        }
        // alternating ±1 around mean 1: EW variance settles near 1
        assert!((e.mean() - 1.0).abs() < 0.2, "mean {}", e.mean());
        assert!(e.variance() > 0.5 && e.variance() < 2.0, "var {}", e.variance());
    }

    #[test]
    #[should_panic(expected = "EWMA weight")]
    fn ewma_rejects_zero_alpha() {
        Ewma::new(0.0);
    }

    #[test]
    fn running_stats_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = RunningStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // sample variance of that classic set is 32/7
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn merge_equals_single_stream() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0 + 1.0).collect();
        let mut whole = RunningStats::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = RunningStats::new();
        let mut b = RunningStats::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.variance() - whole.variance()).abs() < 1e-10);
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut a = RunningStats::new();
        a.push(1.0);
        a.push(3.0);
        let before = (a.count(), a.mean());
        a.merge(&RunningStats::new());
        assert_eq!((a.count(), a.mean()), before);

        let mut e = RunningStats::new();
        e.merge(&a);
        assert_eq!(e.count(), 2);
        assert!((e.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_stats_are_nan() {
        let s = RunningStats::new();
        assert!(s.mean().is_nan());
        assert!(s.variance().is_nan());
        assert!(s.std_err().is_nan());
    }

    #[test]
    fn quantiles() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile_sorted(&v, 0.0), 1.0);
        assert_eq!(quantile_sorted(&v, 1.0), 4.0);
        assert!((quantile_sorted(&v, 0.5) - 2.5).abs() < 1e-12);
        assert!((quantile_sorted(&v, 1.0 / 3.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn quantile_empty_panics() {
        quantile_sorted(&[], 0.5);
    }

    #[test]
    fn streaming_quantiles_exact_below_cap() {
        let mut sq = StreamingQuantiles::new();
        let mut values: Vec<f64> = (0..1000).map(|i| ((i * 7919) % 1000) as f64).collect();
        for &v in &values {
            sq.push(v);
        }
        assert!(sq.is_exact());
        values.sort_unstable_by(f64::total_cmp);
        for q in [0.0, 0.25, 0.5, 0.95, 1.0] {
            assert_eq!(sq.quantile(q), quantile_sorted(&values, q), "q={q}");
        }
    }

    #[test]
    fn streaming_quantiles_grid_within_one_bin_width() {
        let mut sq = StreamingQuantiles::new();
        let n: u64 = 50_000;
        let mut values: Vec<f64> = (0..n)
            .map(|i| {
                // deterministic skewed positive values in (0, ~8)
                let u = ((i.wrapping_mul(2_654_435_761) % n) as f64 + 0.5) / n as f64;
                -(1.0 - u).ln() * 2.0
            })
            .collect();
        for &v in &values {
            sq.push(v);
        }
        assert!(!sq.is_exact());
        values.sort_unstable_by(f64::total_cmp);
        let span = values[values.len() - 1] - values[0];
        let tol = 1.5 * span / StreamingQuantiles::GRID_BINS as f64 * 2.0;
        for q in [0.05, 0.5, 0.95] {
            let exact = quantile_sorted(&values, q);
            let approx = sq.quantile(q);
            assert!(
                (approx - exact).abs() <= tol,
                "q={q}: approx {approx} vs exact {exact} (tol {tol})"
            );
        }
        // monotone in q and clamped to the observed range
        assert!(sq.quantile(0.1) <= sq.quantile(0.9));
        assert!(sq.quantile(0.0) >= values[0] && sq.quantile(1.0) <= values[values.len() - 1]);
    }

    #[test]
    fn streaming_quantiles_merge_matches_single_stream_when_exact() {
        let values: Vec<f64> = (0..2000).map(|i| ((i * 31) % 997) as f64).collect();
        let mut whole = StreamingQuantiles::new();
        values.iter().for_each(|&v| whole.push(v));
        let mut a = StreamingQuantiles::new();
        let mut b = StreamingQuantiles::new();
        values[..700].iter().for_each(|&v| a.push(v));
        values[700..].iter().for_each(|&v| b.push(v));
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        for q in [0.1, 0.5, 0.95] {
            assert_eq!(a.quantile(q), whole.quantile(q), "q={q}");
        }
    }

    #[test]
    fn streaming_quantiles_merge_with_empty_and_into_empty() {
        let mut a = StreamingQuantiles::new();
        a.push(1.0);
        a.push(2.0);
        let before = a.quantile(0.5);
        a.merge(&StreamingQuantiles::new());
        assert_eq!(a.quantile(0.5), before);

        let mut e = StreamingQuantiles::new();
        e.merge(&a);
        assert_eq!(e.count(), 2);
        assert_eq!(e.quantile(0.5), before);
    }

    #[test]
    fn quantiles_with_matches_quantiles_in_both_modes() {
        let mut out = Vec::new();
        let mut scratch = Vec::new();
        let levels = [0.5, 0.9, 0.99];
        // exact mode
        let mut sq = StreamingQuantiles::new();
        (0..1000).for_each(|i| sq.push(((i * 7919) % 1000) as f64));
        assert!(sq.is_exact());
        sq.quantiles_with(&levels, &mut out, &mut scratch);
        assert_eq!(out, sq.quantiles(&levels));
        // grid mode
        let mut sq = StreamingQuantiles::new();
        (0..20_000).for_each(|i| sq.push(((i * 31) % 997) as f64));
        assert!(!sq.is_exact());
        sq.quantiles_with(&levels, &mut out, &mut scratch);
        assert_eq!(out, sq.quantiles(&levels));
    }

    #[test]
    fn streaming_quantiles_constant_stream() {
        let mut sq = StreamingQuantiles::new();
        for _ in 0..10_000 {
            sq.push(3.25);
        }
        assert_eq!(sq.quantile(0.5), 3.25);
        assert_eq!(sq.quantile(0.99), 3.25);
    }
}
