//! Adaptive-subsystem acceptance tests: decision determinism for a
//! fixed seed + arrival trace, estimator-driven re-ranking under
//! drifting worker speeds, and the headline claim — on the
//! shifting-straggler scenario the `order` and `load` policies beat the
//! best *static* scheme's average completion time (EXPERIMENTS.md
//! §Adaptive has the checked-in comparison table).

use straggler_sched::adaptive::{
    run_policy_rounds, two_tier_model, PerRound, PolicyKind, PolicyOutcome, PolicyRunConfig,
    ShiftingStraggler,
};
use straggler_sched::delay::TruncatedGaussianModel;
use straggler_sched::scheme::SchemeId;

/// The canonical shifting-straggler experiment of EXPERIMENTS.md
/// §Adaptive: two-tier fleet (6 of 12 workers 3× slower), slow block
/// rotating every 250 rounds, scarce coverage (r = 4 < n, k = n),
/// light ingestion.  All runs share the delay stream (the policies only
/// consume the scheduling RNG), so comparisons are variance-reduced.
fn shift_run(scheme: SchemeId, policy: PolicyKind, rounds: usize, seed: u64) -> PolicyOutcome {
    shift_run_async(scheme, policy, 1, rounds, seed)
}

/// Same scenario with `S` rounds in flight (bounded staleness; `S = 1`
/// is the synchronous loop).
fn shift_run_async(
    scheme: SchemeId,
    policy: PolicyKind,
    staleness: usize,
    rounds: usize,
    seed: u64,
) -> PolicyOutcome {
    let (n, r, k) = (12usize, 4usize, 12usize);
    let base = two_tier_model(n, 6, 3.0);
    let model = ShiftingStraggler::new(&base, 250, 5);
    run_policy_rounds(
        &PolicyRunConfig {
            scheme,
            policy,
            n,
            r,
            k,
            rounds,
            ingest_ms: 0.05,
            seed,
            staleness,
        },
        &model,
        None,
        None,
    )
    .expect("valid run")
}

#[test]
fn same_seed_and_trace_reproduce_decisions_and_estimates() {
    // alloc-random is excluded here: it needs r = n, and this scenario
    // is the scarce-coverage point r < n (its determinism is covered by
    // the in-module tests)
    for policy in [
        PolicyKind::AdaptiveOrder,
        PolicyKind::AdaptiveLoad,
        PolicyKind::AllocGroup,
    ] {
        let a = shift_run(SchemeId::Gc(4), policy, 600, 77);
        let b = shift_run(SchemeId::Gc(4), policy, 600, 77);
        assert_eq!(
            a.decision_digest, b.decision_digest,
            "{policy}: same seed + trace must replay the same decisions"
        );
        assert_eq!(a.replans, b.replans, "{policy}");
        assert_eq!(
            a.estimate.mean.to_bits(),
            b.estimate.mean.to_bits(),
            "{policy} mean"
        );
        assert_eq!(a.estimate.p95.to_bits(), b.estimate.p95.to_bits(), "{policy} p95");
        // and a different seed sees different arrivals → (almost
        // surely) different decisions
        let c = shift_run(SchemeId::Gc(4), policy, 600, 78);
        assert_ne!(a.estimate.mean.to_bits(), c.estimate.mean.to_bits(), "{policy}");
    }
}

#[test]
fn adaptive_policies_actually_replan_under_drift() {
    let order = shift_run(SchemeId::Gc(4), PolicyKind::AdaptiveOrder, 800, 3);
    // speeds shift every 250 rounds → the ranking must keep changing
    // well past the initial estimate burn-in
    assert!(
        order.replans >= 3,
        "order replanned only {} times over 3 shifts",
        order.replans
    );
    let load = shift_run(SchemeId::Gc(4), PolicyKind::AdaptiveLoad, 800, 3);
    assert!(load.replans >= 3, "load replanned only {} times", load.replans);
    // static allocation variants plan once and freeze
    let group = shift_run(SchemeId::Cs, PolicyKind::AllocGroup, 100, 3);
    assert_eq!(group.replans, 1, "alloc-group is a one-shot override");
}

#[test]
fn shifting_stragglers_adaptive_beats_best_static() {
    // the PR's acceptance bar: on the shifting-straggler scenario both
    // re-planning policies beat the best static scheme's mean.
    // Margins from the calibration run (EXPERIMENTS.md §Adaptive):
    // order ≈ −27%, load ≈ −7% vs the best static — far outside MC
    // noise at 3000 rounds (std errs ≈ 0.3% of the means).
    let rounds = 3000;
    let statics = [
        shift_run(SchemeId::Cs, PolicyKind::Static, rounds, 1),
        shift_run(SchemeId::Gc(4), PolicyKind::Static, rounds, 1),
        shift_run(SchemeId::GcHet(4, 1), PolicyKind::Static, rounds, 1),
    ];
    let best_static = statics
        .iter()
        .map(|o| o.estimate.mean)
        .fold(f64::INFINITY, f64::min);
    let order = shift_run(SchemeId::Gc(4), PolicyKind::AdaptiveOrder, rounds, 1);
    let load = shift_run(SchemeId::Gc(4), PolicyKind::AdaptiveLoad, rounds, 1);
    assert!(
        order.estimate.mean < best_static,
        "AdaptiveOrder {} must beat best static {best_static}",
        order.estimate.mean
    );
    assert!(
        load.estimate.mean < best_static,
        "AdaptiveLoad {} must beat best static {best_static}",
        load.estimate.mean
    );
    // order exploits the spread directly and should win by a wide
    // margin — pin a conservative slice of the calibrated ~27%
    assert!(
        order.estimate.mean < 0.9 * best_static,
        "AdaptiveOrder {} should be ≳10% under best static {best_static}",
        order.estimate.mean
    );
}

#[test]
fn bounded_staleness_beats_best_sync_static_under_shifts() {
    // the PR's async acceptance bar: with S ≥ 2 rounds in flight, fast
    // workers start round t + 1 while the shifted slow tier drags round
    // t to its Stop — per-applied-round wall clock (d_t = apply_t −
    // apply_{t−1}) drops strictly below the best SYNCHRONOUS static
    // scheme, even with no re-planning at all.
    let rounds = 3000;
    let best_sync_static = [
        shift_run(SchemeId::Cs, PolicyKind::Static, rounds, 1),
        shift_run(SchemeId::Gc(4), PolicyKind::Static, rounds, 1),
        shift_run(SchemeId::GcHet(4, 1), PolicyKind::Static, rounds, 1),
    ]
    .iter()
    .map(|o| o.estimate.mean)
    .fold(f64::INFINITY, f64::min);
    let async_static = shift_run_async(SchemeId::Cs, PolicyKind::Static, 2, rounds, 1);
    assert!(
        async_static.estimate.mean < best_sync_static,
        "CS@s2 {} must beat best sync static {best_sync_static}",
        async_static.estimate.mean
    );
    // staleness composes with re-planning: order@s2 must also beat the
    // synchronous order policy (the pipeline is pure overlap, the
    // planner sees the same censored measurements S rounds late)
    let sync_order = shift_run(SchemeId::Gc(4), PolicyKind::AdaptiveOrder, rounds, 1);
    let async_order = shift_run_async(SchemeId::Gc(4), PolicyKind::AdaptiveOrder, 2, rounds, 1);
    assert!(
        async_order.estimate.mean < sync_order.estimate.mean,
        "order@s2 {} must beat sync order {}",
        async_order.estimate.mean,
        sync_order.estimate.mean
    );
    // the labels advertise the window
    assert!(
        async_static.estimate.scheme.ends_with("@s2"),
        "async label: {}",
        async_static.estimate.scheme
    );
}

#[test]
fn stationary_fleet_leaves_little_for_adaptation() {
    // sanity check against over-claiming: on a *homogeneous stationary*
    // fleet, re-ranking cannot find structure — adaptive order must be
    // within noise of static GC(4), not magically better
    let (n, r, k) = (12usize, 4usize, 12usize);
    let model = TruncatedGaussianModel::scenario1(n);
    let run = |policy| {
        run_policy_rounds(
            &PolicyRunConfig {
                scheme: SchemeId::Gc(4),
                policy,
                n,
                r,
                k,
                rounds: 2500,
                ingest_ms: 0.05,
                seed: 9,
                staleness: 1,
            },
            &PerRound(&model),
            None,
            None,
        )
        .unwrap()
    };
    let frozen = run(PolicyKind::Static);
    let order = run(PolicyKind::AdaptiveOrder);
    let slack = 5.0 * (frozen.estimate.std_err + order.estimate.std_err);
    assert!(
        (order.estimate.mean - frozen.estimate.mean).abs() < slack.max(0.05),
        "homogeneous fleet: order {} vs static {} should agree",
        order.estimate.mean,
        frozen.estimate.mean
    );
}

#[test]
fn estimator_recovers_the_true_tiers_from_censored_feedback() {
    // after a run on the (non-shifting) two-tier fleet, the engine's
    // estimates must separate the tiers despite completion-censored
    // observations — check via the outcome of a load run whose sizes
    // encode the ranking: slow workers must hold the small sizes.
    // two_tier_model makes workers 0..6 the slow ones.
    let (n, r, k) = (12usize, 4usize, 12usize);
    let base = two_tier_model(n, 6, 3.0);
    let mut last_round_mean = 0.0;
    let mut first_rounds_mean = 0.0;
    let mut count = 0usize;
    {
        let mut emit = |round: usize, t: f64| {
            if round < 200 {
                first_rounds_mean += t;
                count += 1;
            } else {
                last_round_mean += t;
            }
        };
        run_policy_rounds(
            &PolicyRunConfig {
                scheme: SchemeId::Gc(4),
                policy: PolicyKind::AdaptiveOrder,
                n,
                r,
                k,
                rounds: 400,
                ingest_ms: 0.05,
                seed: 5,
                staleness: 1,
            },
            &PerRound(&base),
            Some(&mut emit),
            None,
        )
        .unwrap();
    }
    first_rounds_mean /= count as f64;
    last_round_mean /= 200.0;
    // once the estimator has locked on, later rounds should not be
    // slower than the burn-in on a stationary fleet
    assert!(
        last_round_mean <= first_rounds_mean * 1.05,
        "burn-in {first_rounds_mean} → settled {last_round_mean}"
    );
}

#[test]
fn emit_streams_every_round_in_order() {
    let mut seen = Vec::new();
    let model = TruncatedGaussianModel::scenario1(4);
    let mut emit = |round: usize, t: f64| seen.push((round, t));
    run_policy_rounds(
        &PolicyRunConfig {
            scheme: SchemeId::Cs,
            policy: PolicyKind::AdaptiveOrder,
            n: 4,
            r: 2,
            k: 3,
            rounds: 300,
            ingest_ms: 0.0,
            seed: 2,
            staleness: 1,
        },
        &PerRound(&model),
        Some(&mut emit),
        None,
    )
    .unwrap();
    assert_eq!(seen.len(), 300);
    assert!(seen.iter().enumerate().all(|(i, &(r, _))| i == r));
    assert!(seen.iter().all(|&(_, t)| t.is_finite() && t > 0.0));
}
