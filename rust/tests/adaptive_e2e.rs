//! Live-cluster integration of the adaptive subsystem: real sockets,
//! protocol v3 frames, policy-driven per-round `Assign` plans, and the
//! GCH per-worker-cadence unlock (divisor-snapped flush sizes merging
//! duplicate-safe on the master).

use straggler_sched::adaptive::PolicyKind;
use straggler_sched::coordinator::{run_cluster, ClusterConfig, IoMode};
use straggler_sched::data::Dataset;
use straggler_sched::delay::DelayModelKind;
use straggler_sched::scheme::{SchemeId, SchemeRegistry};

fn config(
    scheme: SchemeId,
    policy: PolicyKind,
    n: usize,
    r: usize,
    k: usize,
    rounds: usize,
) -> ClusterConfig {
    ClusterConfig {
        n,
        r,
        k,
        eta: 0.05,
        rounds,
        profile: "quickstart".into(),
        plan: SchemeRegistry::adaptive_plan(scheme, policy, n, r, k)
            .unwrap_or_else(|e| panic!("{scheme}+{policy} plan: {e:#}")),
        policy,
        staleness: 1,
        dataset: Dataset::synthesize(n, 16, n * 8, 42),
        inject: Some(DelayModelKind::Ec2Like {
            seed: 11,
            hetero: 0.3,
        }),
        seed: 7,
        use_pjrt: false,
        artifact_dir: None,
        loss_every: 1,
        listen: None,
        spawn_workers: true,
        io: IoMode::default(),
        metrics: Default::default(),
    }
}

#[test]
fn gch_runs_live_with_heterogeneous_cadences() {
    // the unlocked GCH cluster plan: per-worker flush sizes [2, 2, 1, 1]
    // (ramp 2→1 snapped to divisors of 2) must merge duplicate-safe and
    // converge exactly like the uniform schemes
    let cfg = config(SchemeId::GcHet(2, 1), PolicyKind::Static, 4, 4, 4, 60);
    let sizes = cfg.plan.groups.clone().expect("per-worker sizes");
    assert_eq!(sizes, vec![2, 2, 1, 1]);
    let ds = cfg.dataset.clone();
    let l0 = ds.loss(&vec![0.0; ds.d]);
    let report = run_cluster(cfg).expect("GCH cluster run");
    assert_eq!(report.rounds.len(), 60);
    for log in &report.rounds {
        // k = n: every task delivered exactly once into θ
        assert_eq!(log.winners.len(), 4, "round {}", log.round);
        let mut w = log.winners.clone();
        w.sort_unstable();
        assert_eq!(w, vec![0, 1, 2, 3], "round {}", log.round);
        assert!(!log.replanned, "static policy never replans");
    }
    assert!(
        report.final_loss < 0.2 * l0,
        "GCH training must converge: {l0} → {}",
        report.final_loss
    );
    assert!(report.worker_estimates.is_empty(), "static runs carry no estimator");
}

#[test]
fn order_policy_replans_live_rounds_and_reports_estimates() {
    let cfg = config(SchemeId::Gc(2), PolicyKind::AdaptiveOrder, 4, 4, 4, 50);
    let ds = cfg.dataset.clone();
    let l0 = ds.loss(&vec![0.0; ds.d]);
    let report = run_cluster(cfg).expect("order-policy cluster run");
    assert_eq!(report.rounds.len(), 50);
    assert!(
        report.rounds.iter().any(|l| l.replanned),
        "the order policy must re-plan at least once over 50 measured rounds"
    );
    // every worker was measured and estimated
    assert_eq!(report.worker_estimates.len(), 4);
    for e in &report.worker_estimates {
        assert!(e.samples > 0, "worker {} unobserved", e.worker);
        assert!(e.comp_mean_ms.is_finite() && e.comp_mean_ms > 0.0);
        assert!(e.comm_mean_ms.is_finite() && e.comm_mean_ms > 0.0);
    }
    assert!(
        report.final_loss < 0.2 * l0,
        "re-planned training must still converge: {l0} → {}",
        report.final_loss
    );
}

#[test]
fn load_policy_resizes_cadences_without_corrupting_theta() {
    let cfg = config(SchemeId::Gc(2), PolicyKind::AdaptiveLoad, 4, 4, 4, 50);
    let ds = cfg.dataset.clone();
    let l0 = ds.loss(&vec![0.0; ds.d]);
    let report = run_cluster(cfg).expect("load-policy cluster run");
    // k = n + duplicate-safe merge ⇒ every round applies the exact
    // full gradient regardless of the cadence re-splits
    for log in &report.rounds {
        let mut w = log.winners.clone();
        w.sort_unstable();
        assert_eq!(w, vec![0, 1, 2, 3], "round {}", log.round);
    }
    assert!(
        report.final_loss < 0.2 * l0,
        "load-policy training must converge: {l0} → {}",
        report.final_loss
    );
    assert!(report.rounds.iter().any(|l| l.replanned));
}

#[test]
fn alloc_group_policy_partitions_the_live_fleet() {
    // group allocation at n = 4, r = 2: two worker pairs, each
    // replicating a 2-task batch; k = 2 completes on the faster pair
    let cfg = config(SchemeId::Cs, PolicyKind::AllocGroup, 4, 2, 2, 40);
    let report = run_cluster(cfg).expect("alloc-group cluster run");
    assert_eq!(report.rounds.len(), 40);
    for log in &report.rounds {
        let mut w = log.winners.clone();
        w.sort_unstable();
        w.dedup();
        assert_eq!(w.len(), log.winners.len(), "winners distinct");
        // CS base ⇒ singleton flushes ⇒ the round stops at exactly k
        assert_eq!(log.winners.len(), 2, "round {}", log.round);
    }
    // the one-shot override plans exactly once
    assert_eq!(
        report.rounds.iter().filter(|l| l.replanned).count(),
        1,
        "alloc-group is a frozen override after round 0"
    );
}
