//! Property tests for the batched structure-of-arrays Monte-Carlo
//! engine (PR 1 tentpole):
//!
//! 1. per model: `sample_batch_into` is **bit-identical** to B
//!    sequential `sample_into` calls (delays *and* RNG stream);
//! 2. `completion_times_batch` is bit-identical to
//!    `completion_time_fast` across random TO matrices and models;
//! 3. the streaming estimator's grid quantiles track exact quantiles
//!    within the documented one-bin tolerance;
//! 4. the batched estimator reproduces the scalar estimator exactly
//!    for fixed `(trials, threads, seed)`.

use straggler_sched::delay::{
    DelayBatch, DelayModel, DelaySample, Ec2LikeModel, EmpiricalModel, Scaled,
    ShiftedExponential, Trace, TruncatedGaussianModel, WorkerCorrelated,
};
use straggler_sched::scheduler::{
    CyclicScheduler, RandomAssignment, Scheduler, StaircaseScheduler,
};
use straggler_sched::sim::{
    completion_time_fast, completion_times_batch, MonteCarlo,
};
use straggler_sched::util::rng::Rng;
use straggler_sched::util::stats::{quantile_sorted, StreamingQuantiles};

fn models_under_test(n: usize) -> Vec<(&'static str, Box<dyn DelayModel>)> {
    let traces: Vec<Trace> = (0..n)
        .map(|i| Trace::new(vec![0.5 + i as f64 * 0.1, 1.0, 1.5, 2.0 + i as f64 * 0.05]))
        .collect();
    vec![
        (
            "truncated-gaussian/scenario1",
            Box::new(TruncatedGaussianModel::scenario1(n)) as Box<dyn DelayModel>,
        ),
        (
            "truncated-gaussian/scenario2",
            Box::new(TruncatedGaussianModel::scenario2(n, 21)),
        ),
        (
            "shifted-exp",
            Box::new(ShiftedExponential::new(0.08, 6.0, 0.3, 2.5)),
        ),
        (
            "scaled(shifted-exp)",
            Box::new(Scaled::new(ShiftedExponential::new(0.08, 6.0, 0.3, 2.5), 1.7, 0.6)),
        ),
        (
            "correlated(shifted-exp)",
            Box::new(WorkerCorrelated::new(
                ShiftedExponential::new(0.08, 6.0, 0.3, 2.5),
                0.7,
            )),
        ),
        (
            "empirical",
            Box::new(EmpiricalModel::new(traces.clone(), traces)),
        ),
        ("ec2-like", Box::new(Ec2LikeModel::new(n, 5, 0.25))),
    ]
}

#[test]
fn sample_batch_into_bit_identical_to_sequential_sampling() {
    let (n, r, rounds) = (6usize, 4usize, 23usize);
    for (name, model) in models_under_test(n) {
        for seed in 0..5u64 {
            let mut rng_batch = Rng::seed_from_u64(0xABCD ^ seed);
            let mut rng_seq = Rng::seed_from_u64(0xABCD ^ seed);
            let mut batch = DelayBatch::zeros(rounds, n, r);
            model.sample_batch_into(&mut batch, &mut rng_batch);
            let mut tmp = DelaySample::zeros(n, r);
            for b in 0..rounds {
                model.sample_into(&mut tmp, &mut rng_seq);
                for (slot, (&bv, &sv)) in batch
                    .comp_round(b)
                    .iter()
                    .zip(tmp.comp_flat())
                    .enumerate()
                {
                    assert_eq!(
                        bv.to_bits(),
                        sv.to_bits(),
                        "{name} seed {seed} round {b} comp slot {slot}: {bv} vs {sv}"
                    );
                }
                for (slot, (&bv, &sv)) in batch
                    .comm_round(b)
                    .iter()
                    .zip(tmp.comm_flat())
                    .enumerate()
                {
                    assert_eq!(
                        bv.to_bits(),
                        sv.to_bits(),
                        "{name} seed {seed} round {b} comm slot {slot}: {bv} vs {sv}"
                    );
                }
            }
            // the RNG streams must have advanced identically too
            assert_eq!(
                rng_batch.next_u64(),
                rng_seq.next_u64(),
                "{name} seed {seed}: RNG streams diverged"
            );
        }
    }
}

#[test]
fn completion_times_batch_bit_identical_across_random_matrices() {
    let mut meta_rng = Rng::seed_from_u64(0xC0DE);
    for case in 0..40u32 {
        let n = 2 + meta_rng.below(10);
        let r = 1 + meta_rng.below(n);
        let rounds = 1 + meta_rng.below(48);
        let model: Box<dyn DelayModel> = {
            let mut models = models_under_test(n);
            let idx = meta_rng.below(models.len());
            models.swap_remove(idx).1
        };
        let sched: Box<dyn Scheduler> = match meta_rng.below(3) {
            0 => Box::new(CyclicScheduler),
            1 => Box::new(StaircaseScheduler),
            _ => Box::new(RandomAssignment),
        };
        let to = if sched.is_randomized() && r != n {
            // RA requires r = n; fall back to CS for that shape
            CyclicScheduler.schedule(n, r, &mut meta_rng)
        } else {
            sched.schedule(n, r, &mut meta_rng)
        };
        let batch = model.sample_batch(rounds, n, r, &mut meta_rng);
        let covered = to.coverage().iter().filter(|&&c| c > 0).count();
        let k = 1 + meta_rng.below(covered);
        let mut batched = Vec::new();
        completion_times_batch(&to, &batch, k, &mut batched);
        assert_eq!(batched.len(), rounds);
        let mut scratch: Vec<f64> = Vec::new();
        for b in 0..rounds {
            let sample = batch.round_sample(b);
            let scalar = completion_time_fast(&to, &sample, k, &mut scratch);
            assert_eq!(
                batched[b].to_bits(),
                scalar.to_bits(),
                "case {case}: n={n} r={r} k={k} round {b}"
            );
        }
    }
}

#[test]
fn streaming_quantiles_track_exact_quantiles_on_mc_output() {
    // real engine output, past the exact-mode cap: grid quantiles must
    // sit within one (margined) bin width of the exact order statistics
    let model = TruncatedGaussianModel::scenario1(8);
    let mc = MonteCarlo {
        trials: 30_000,
        seed: 99,
        threads: 4,
    };
    let raw = mc.run_coupled(&[&CyclicScheduler], &model, 8, 4, 8).remove(0);
    assert_eq!(raw.len(), 30_000);
    let mut sorted = raw.clone();
    sorted.sort_unstable_by(f64::total_cmp);

    let est = mc.estimate(&CyclicScheduler, &model, 8, 4, 8);
    let span = sorted[sorted.len() - 1] - sorted[0];
    // a few grid bins of the 1.5×-span grid: one for in-bin
    // interpolation plus re-binning slack from the shard merges
    let tol = 4.0 * 1.5 * span / StreamingQuantiles::GRID_BINS as f64;
    for (q, got) in [(0.5, est.p50), (0.95, est.p95)] {
        let exact = quantile_sorted(&sorted, q);
        assert!(
            (got - exact).abs() <= tol,
            "q={q}: streaming {got} vs exact {exact} (tol {tol}, span {span})"
        );
    }
    assert!(est.min <= est.p50 && est.p50 <= est.p95 && est.p95 <= est.max);
}

#[test]
fn batched_and_scalar_estimators_agree_exactly_multithreaded() {
    let model = Ec2LikeModel::new(10, 17, 0.2);
    let mc = MonteCarlo {
        trials: 4096,
        seed: 0xFEED,
        threads: 8,
    };
    let schemes: Vec<&dyn Scheduler> =
        vec![&CyclicScheduler, &StaircaseScheduler, &RandomAssignment];
    let batched = mc.estimate_coupled(&schemes, &model, 10, 10, 10);
    let scalar = mc.estimate_coupled_scalar(&schemes, &model, 10, 10, 10);
    for (a, b) in batched.iter().zip(&scalar) {
        assert_eq!(a.trials, b.trials, "{}", a.scheme);
        assert_eq!(a.mean.to_bits(), b.mean.to_bits(), "{} mean", a.scheme);
        assert_eq!(a.std_dev.to_bits(), b.std_dev.to_bits(), "{} std", a.scheme);
        assert_eq!(a.p50.to_bits(), b.p50.to_bits(), "{} p50", a.scheme);
        assert_eq!(a.p95.to_bits(), b.p95.to_bits(), "{} p95", a.scheme);
    }
}
