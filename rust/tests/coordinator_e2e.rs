//! End-to-end cluster integration: real sockets, real protocol v4
//! (aggregated partial-sum frames with θ-version tags), real compute,
//! paper-§II round semantics, registry-dispatched scheme plans —
//! including coded PC/PCMM rounds that decode on the master and update
//! θ, and bounded-staleness pipelined rounds (S ≥ 2 in flight).

use std::net::TcpListener;

use straggler_sched::adaptive::PolicyKind;
use straggler_sched::coordinator::{run_cluster, run_worker, ClusterConfig, IoMode, WorkerOptions};
use straggler_sched::data::Dataset;
use straggler_sched::delay::DelayModelKind;
use straggler_sched::scheme::{CompletionRule, SchemeId, SchemeRegistry};

fn base_config(scheme: SchemeId, n: usize, r: usize, k: usize, rounds: usize) -> ClusterConfig {
    ClusterConfig {
        n,
        r,
        k,
        eta: 0.05,
        rounds,
        profile: "quickstart".into(),
        plan: SchemeRegistry::cluster_plan(scheme, n, r, k)
            .unwrap_or_else(|e| panic!("{scheme} plan at (n={n}, r={r}, k={k}): {e:#}")),
        policy: PolicyKind::Static,
        staleness: 1,
        dataset: Dataset::synthesize(n, 16, n * 8, 42),
        inject: Some(DelayModelKind::TruncatedGaussianScenario1),
        seed: 7,
        use_pjrt: false,
        artifact_dir: None,
        loss_every: 1,
        listen: None,
        spawn_workers: true,
        io: IoMode::default(),
        metrics: Default::default(),
    }
}

#[test]
fn cluster_round_delivers_k_distinct_and_converges() {
    let cfg = base_config(SchemeId::Cs, 4, 2, 4, 60);
    let ds = cfg.dataset.clone();
    let l0 = ds.loss(&vec![0.0; ds.d]);
    let report = run_cluster(cfg).expect("cluster run");
    assert_eq!(report.rounds.len(), 60);
    for log in &report.rounds {
        // exactly k distinct winners
        assert_eq!(log.winners.len(), 4, "round {}", log.round);
        let mut w = log.winners.clone();
        w.sort_unstable();
        w.dedup();
        assert_eq!(w.len(), 4, "winners must be distinct");
        assert!(log.completion_ms > 0.0);
        assert!(log.wire_bytes > 0);
    }
    assert!(
        report.final_loss < 0.2 * l0,
        "loss should drop: {l0} → {}",
        report.final_loss
    );
}

#[test]
fn cluster_completion_reflects_injected_delays() {
    // scenario 1: comp ≈ 0.1 ms, comm ≈ 0.5 ms; a k = n round needs at
    // least one full comp+comm ≈ 0.6 ms and should stay well under the
    // several-ms mark on an unloaded box
    let cfg = base_config(SchemeId::Cs, 4, 4, 4, 40);
    let report = run_cluster(cfg).expect("cluster run");
    let mean = report.mean_completion_ms();
    assert!(mean > 0.6, "mean completion {mean} ms below physical floor");
    assert!(mean < 25.0, "mean completion {mean} ms implausibly high");
    // measured comm should dominate measured comp (Fig. 3 shape);
    // comp records include the injected sleep
    let comp_mean = report.recorders[0].comp_stats().mean();
    let comm_mean = report.recorders[0].comm_stats().mean();
    assert!(comm_mean > comp_mean, "comm {comm_mean} !> comp {comp_mean}");
}

#[test]
fn cluster_supports_all_uncoded_schemes_through_registry() {
    for id in [SchemeId::Cs, SchemeId::Ss, SchemeId::Ra] {
        let n = 4;
        let cfg = base_config(id, n, n, 3, 10);
        let report = run_cluster(cfg).unwrap_or_else(|e| panic!("{id}: {e:#}"));
        assert_eq!(report.rounds.len(), 10, "{id}");
        for log in &report.rounds {
            assert_eq!(log.winners.len(), 3, "{id}");
        }
    }
}

#[test]
fn cluster_partial_target_sees_fewer_results_than_full_work() {
    // with k = 2 of n = 4 the master acks early; workers should abandon
    // the tail, so results_seen stays well below n·r on average
    let cfg = base_config(SchemeId::Cs, 4, 4, 2, 30);
    let report = run_cluster(cfg).expect("cluster run");
    let avg_results: f64 = report
        .rounds
        .iter()
        .map(|l| l.results_seen as f64)
        .sum::<f64>()
        / 30.0;
    assert!(
        avg_results < 12.0,
        "stop ack should curtail work: avg {avg_results} results/round of 16 max"
    );
}

#[test]
fn cluster_executes_gc_grouped_scheme_through_registry_plan() {
    // GC(2) via the registry's ClusterPlan: workers flush one
    // aggregated partial-sum block per canonical 2-task range; training
    // still converges and the message economy is visible in the logs
    let n = 4;
    let cfg = base_config(SchemeId::Gc(2), n, n, n, 60);
    let ds = cfg.dataset.clone();
    let l0 = ds.loss(&vec![0.0; ds.d]);
    let report = run_cluster(cfg).expect("GC cluster run");
    assert_eq!(report.rounds.len(), 60);
    let (mut total_msgs, mut total_results) = (0usize, 0usize);
    for log in &report.rounds {
        assert_eq!(log.winners.len(), n, "round {}", log.round);
        let mut w = log.winners.clone();
        w.sort_unstable();
        w.dedup();
        assert_eq!(w.len(), n, "winners must be distinct");
        // aligned flushing: workers starting on a block boundary send
        // 2-task ranges, the others send 1-2-1; never more than r tasks
        assert!(log.results_seen <= n * n, "round {}", log.round);
        assert!(log.results_seen >= log.messages_seen, "round {}", log.round);
        total_msgs += log.messages_seen;
        total_results += log.results_seen;
    }
    assert!(
        total_results as f64 > 1.2 * total_msgs as f64,
        "grouping must deliver >1 task/message on average: \
         {total_results} results over {total_msgs} messages"
    );
    assert!(
        report.final_loss < 0.2 * l0,
        "GC training should converge: {l0} → {}",
        report.final_loss
    );
}

#[test]
fn gc_wire_bytes_shrink_versus_immediate_streaming() {
    // the v3 acceptance bar: a GC(s) flush ships ONE d-block no matter
    // how many tasks it aggregates, so wire bytes *per delivered
    // result* must drop materially below GC(1)'s one-frame-per-task
    // cost (the s× payload shrink vs the PR-2 concatenated-block wire,
    // measured; see EXPERIMENTS.md §Schemes for the frame arithmetic)
    let n = 4;
    let run = |s: u32| {
        let cfg = base_config(SchemeId::Gc(s), n, n, n, 40);
        run_cluster(cfg).expect("gc run")
    };
    let gc1 = run(1);
    let gc2 = run(2);
    let per_result = |rep: &straggler_sched::coordinator::ClusterReport| {
        let bytes: usize = rep.rounds.iter().map(|l| l.wire_bytes).sum();
        let results: usize = rep.rounds.iter().map(|l| l.results_seen).sum();
        bytes as f64 / results.max(1) as f64
    };
    let (b1, b2) = (per_result(&gc1), per_result(&gc2));
    assert!(
        b2 < 0.8 * b1,
        "GC(2) must ship materially fewer bytes per result than GC(1): {b2} vs {b1}"
    );
    // and θ still reaches a comparable optimum (exactness across s is
    // pinned bit-level by tests/partial_sum.rs; the live wire adds only
    // f32 rounding)
    assert!(gc2.final_loss < 1.5 * gc1.final_loss + 1e-3);
}

#[test]
fn async_cluster_pipelines_two_rounds_in_flight() {
    // the tentpole e2e: S = 2 bounded staleness over real sockets — the
    // master issues round t + 1 tagged with the pre-apply θ-version the
    // moment the ring has a free slot, applies strictly oldest-first,
    // and training still converges (gap ≤ 1 gradient staleness)
    let (n, rounds) = (4usize, 60usize);
    let mut cfg = base_config(SchemeId::Cs, n, 2, n, rounds);
    cfg.staleness = 2;
    let ds = cfg.dataset.clone();
    let l0 = ds.loss(&vec![0.0; ds.d]);
    let report = run_cluster(cfg).expect("async cluster run");
    assert_eq!(report.rounds.len(), rounds);
    for (i, log) in report.rounds.iter().enumerate() {
        // applies are strictly in order — the ring retires oldest-first
        assert_eq!(log.round, i, "apply order");
        assert_eq!(log.winners.len(), n, "round {}", log.round);
        let mut w = log.winners.clone();
        w.sort_unstable();
        w.dedup();
        assert_eq!(w.len(), n, "winners must be distinct");
        assert!(log.completion_ms > 0.0);
        assert!(log.wire_bytes > 0);
    }
    assert!(report.final_theta.iter().all(|t| t.is_finite()));
    assert!(
        report.final_loss < 0.3 * l0,
        "stale gradients (gap ≤ 1) must still converge: {l0} → {}",
        report.final_loss
    );
}

#[test]
fn async_cluster_rejects_unsupported_plans() {
    // S ≥ 2 is gated to uncoded immediate-streaming plans: grouped and
    // coded wires would need per-version decode state the ring does not
    // carry (documented in EXPERIMENTS.md §Async)
    let mut cfg = base_config(SchemeId::Gc(2), 4, 4, 4, 5);
    cfg.staleness = 2;
    let err = format!("{:#}", run_cluster(cfg).expect_err("GC@s2 must be rejected"));
    assert!(err.contains("staleness"), "unexpected error: {err}");
    // and the window itself is bounded
    let mut cfg = base_config(SchemeId::Cs, 4, 2, 4, 5);
    cfg.staleness = 0;
    assert!(run_cluster(cfg).is_err(), "S = 0 is not a window");
}

/// Oracle reference: `rounds` full-gradient GD steps (eq. 48/49).
fn oracle_gd(ds: &Dataset, eta: f64, rounds: usize) -> Vec<f64> {
    let mut theta = vec![0.0; ds.d];
    for _ in 0..rounds {
        let g = ds.full_gradient(&theta);
        for (t, gi) in theta.iter_mut().zip(&g) {
            *t -= eta * gi;
        }
    }
    theta
}

#[test]
fn pc_rounds_decode_on_master_and_match_uncoded_gradient() {
    // PC wire: the master encodes each worker's r Lagrange-mixed
    // matrices, collects one φ(x_i) evaluation per worker, decodes at
    // 2⌈n/r⌉ − 1 and steps θ with the exact full gradient — so the
    // trajectory must track plain full-gradient descent up to f32 wire
    // rounding (the exact-recovery property of coded::pc, live)
    let (n, r, rounds) = (4usize, 2usize, 15usize);
    let cfg = base_config(SchemeId::Pc, n, r, n, rounds);
    assert_eq!(
        cfg.plan.rule,
        CompletionRule::Messages { threshold: 3 },
        "PC recovery threshold at n=4, r=2"
    );
    let ds = cfg.dataset.clone();
    let eta = cfg.eta;
    let l0 = ds.loss(&vec![0.0; ds.d]);
    let report = run_cluster(cfg).expect("PC cluster run");
    assert_eq!(report.rounds.len(), rounds);
    for log in &report.rounds {
        assert_eq!(log.messages_seen, 3, "round {}", log.round);
        // winners are worker keys under the coded wire
        assert!(log.winners.iter().all(|&w| w < n));
    }
    let want = oracle_gd(&ds, eta, rounds);
    for i in 0..ds.d {
        assert!(
            (report.final_theta[i] - want[i]).abs() < 5e-3 * (1.0 + want[i].abs()),
            "coord {i}: decoded trajectory {} vs oracle {}",
            report.final_theta[i],
            want[i]
        );
    }
    assert!(
        report.final_loss < 0.5 * l0,
        "PC training must reduce loss: {l0} → {}",
        report.final_loss
    );
}

#[test]
fn pcmm_rounds_decode_on_master_and_match_uncoded_gradient() {
    // PCMM wire: immediate streaming of ψ(β_{i,j}) evaluations, decode
    // at 2n − 1 — θ updates every round instead of staying frozen
    let (n, r, rounds) = (4usize, 2usize, 15usize);
    let cfg = base_config(SchemeId::Pcmm, n, r, n, rounds);
    assert_eq!(cfg.plan.rule, CompletionRule::Messages { threshold: 7 });
    let ds = cfg.dataset.clone();
    let eta = cfg.eta;
    let l0 = ds.loss(&vec![0.0; ds.d]);
    let report = run_cluster(cfg).expect("PCMM cluster run");
    assert_eq!(report.rounds.len(), rounds);
    for log in &report.rounds {
        assert_eq!(log.messages_seen, 7, "round {}", log.round);
        // winners are global slot ids under the PCMM wire
        assert!(log.winners.iter().all(|&slot| slot < n * r));
        let mut w = log.winners.clone();
        w.sort_unstable();
        w.dedup();
        assert_eq!(w.len(), 7, "evaluation points must be distinct");
    }
    let want = oracle_gd(&ds, eta, rounds);
    for i in 0..ds.d {
        assert!(
            (report.final_theta[i] - want[i]).abs() < 5e-3 * (1.0 + want[i].abs()),
            "coord {i}: decoded trajectory {} vs oracle {}",
            report.final_theta[i],
            want[i]
        );
    }
    assert!(
        report.final_loss < 0.5 * l0,
        "PCMM training must reduce loss: {l0} → {}",
        report.final_loss
    );
}

#[test]
fn worker_rejects_protocol_version_skew() {
    // regression for the v2 → v3 bump: a version-skewed peer must fail
    // the handshake with a clear message, never mis-decode frames
    use straggler_sched::coordinator::protocol::{Msg, PROTO_VERSION};

    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap();
    let master = std::thread::spawn(move || {
        let (stream, _) = listener.accept().expect("accept");
        Msg::Welcome {
            proto: PROTO_VERSION - 1,
            worker_id: 0,
            profile: "quickstart".into(),
        }
        .write_to(&mut &stream)
        .expect("send stale welcome");
        stream
    });
    let err = run_worker(
        addr,
        WorkerOptions {
            backend: straggler_sched::coordinator::Backend::CpuOracle,
            injected: None,
            artifact_dir: None,
        },
    )
    .expect_err("v2 handshake must be rejected");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("protocol version mismatch"),
        "unexpected error: {msg}"
    );
    drop(master.join().expect("master thread"));
}

#[test]
fn cluster_with_pjrt_backend_runs_if_artifacts_present() {
    let dir = straggler_sched::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping PJRT cluster test: artifacts not built");
        return;
    }
    // quickstart profile: d = 64, b = 32, n = 4
    let mut cfg = base_config(SchemeId::Cs, 4, 2, 4, 15);
    cfg.dataset = Dataset::synthesize(4, 64, 4 * 32, 5);
    cfg.use_pjrt = true;
    let ds = cfg.dataset.clone();
    let l0 = ds.loss(&vec![0.0; ds.d]);
    let report = run_cluster(cfg).expect("PJRT cluster run");
    assert!(
        report.final_loss < l0,
        "PJRT-backed training must reduce loss: {l0} → {}",
        report.final_loss
    );
}
