//! End-to-end cluster integration: real sockets, real protocol, real
//! compute, paper-§II round semantics.

use straggler_sched::coordinator::{run_cluster, ClusterConfig};
use straggler_sched::data::Dataset;
use straggler_sched::delay::DelayModelKind;
use straggler_sched::scheduler::{CyclicScheduler, RandomAssignment, StaircaseScheduler};
use straggler_sched::scheme::{CompletionRule, SchemeId, SchemeRegistry};

fn base_config(n: usize, r: usize, k: usize, rounds: usize) -> ClusterConfig {
    ClusterConfig {
        n,
        r,
        k,
        eta: 0.05,
        rounds,
        profile: "quickstart".into(),
        scheduler: Box::new(CyclicScheduler),
        dataset: Dataset::synthesize(n, 16, n * 8, 42),
        inject: Some(DelayModelKind::TruncatedGaussianScenario1),
        seed: 7,
        use_pjrt: false,
        artifact_dir: None,
        loss_every: 1,
        listen: None,
        spawn_workers: true,
        group: 1,
        rule: CompletionRule::DistinctTasks,
    }
}

#[test]
fn cluster_round_delivers_k_distinct_and_converges() {
    let cfg = base_config(4, 2, 4, 60);
    let ds = cfg.dataset.clone();
    let l0 = ds.loss(&vec![0.0; ds.d]);
    let report = run_cluster(cfg).expect("cluster run");
    assert_eq!(report.rounds.len(), 60);
    for log in &report.rounds {
        // exactly k distinct winners
        assert_eq!(log.winners.len(), 4, "round {}", log.round);
        let mut w = log.winners.clone();
        w.sort_unstable();
        w.dedup();
        assert_eq!(w.len(), 4, "winners must be distinct");
        assert!(log.completion_ms > 0.0);
    }
    assert!(
        report.final_loss < 0.2 * l0,
        "loss should drop: {l0} → {}",
        report.final_loss
    );
}

#[test]
fn cluster_completion_reflects_injected_delays() {
    // scenario 1: comp ≈ 0.1 ms, comm ≈ 0.5 ms; a k = n round needs at
    // least one full comp+comm ≈ 0.6 ms and should stay well under the
    // several-ms mark on an unloaded box
    let cfg = base_config(4, 4, 4, 40);
    let report = run_cluster(cfg).expect("cluster run");
    let mean = report.mean_completion_ms();
    assert!(mean > 0.6, "mean completion {mean} ms below physical floor");
    assert!(mean < 25.0, "mean completion {mean} ms implausibly high");
    // measured comm should dominate measured comp (Fig. 3 shape);
    // comp records include the injected sleep
    let comp_mean = report.recorders[0].comp_stats().mean();
    let comm_mean = report.recorders[0].comm_stats().mean();
    assert!(comm_mean > comp_mean, "comm {comm_mean} !> comp {comp_mean}");
}

#[test]
fn cluster_supports_all_uncoded_schedulers() {
    for (name, sched) in [
        ("CS", Box::new(CyclicScheduler) as Box<dyn straggler_sched::scheduler::Scheduler>),
        ("SS", Box::new(StaircaseScheduler)),
        ("RA", Box::new(RandomAssignment)),
    ] {
        let n = 4;
        let mut cfg = base_config(n, n, 3, 10);
        cfg.scheduler = sched;
        let report = run_cluster(cfg).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_eq!(report.rounds.len(), 10, "{name}");
        for log in &report.rounds {
            assert_eq!(log.winners.len(), 3, "{name}");
        }
    }
}

#[test]
fn cluster_partial_target_sees_fewer_results_than_full_work() {
    // with k = 2 of n = 4 the master acks early; workers should abandon
    // the tail, so results_seen stays well below n·r on average
    let cfg = base_config(4, 4, 2, 30);
    let report = run_cluster(cfg).expect("cluster run");
    let avg_results: f64 = report
        .rounds
        .iter()
        .map(|l| l.results_seen as f64)
        .sum::<f64>()
        / 30.0;
    assert!(
        avg_results < 12.0,
        "stop ack should curtail work: avg {avg_results} results/round of 16 max"
    );
}

#[test]
fn cluster_executes_gc_grouped_scheme_through_registry_plan() {
    // GC(2) via the registry's ClusterPlan: workers flush one message
    // per 2 completed tasks; training still converges and the message
    // economy is visible in the round logs
    let n = 4;
    let plan = SchemeRegistry::cluster_plan(SchemeId::Gc(2), n, n, n).unwrap();
    let mut cfg = base_config(n, n, n, 60);
    cfg.scheduler = plan.scheduler;
    cfg.group = plan.group;
    cfg.rule = plan.rule;
    let ds = cfg.dataset.clone();
    let l0 = ds.loss(&vec![0.0; ds.d]);
    let report = run_cluster(cfg).expect("GC cluster run");
    assert_eq!(report.rounds.len(), 60);
    for log in &report.rounds {
        assert_eq!(log.winners.len(), n, "round {}", log.round);
        let mut w = log.winners.clone();
        w.sort_unstable();
        w.dedup();
        assert_eq!(w.len(), n, "winners must be distinct");
        // every message carries exactly group = 2 results (r divisible
        // by s, and partially-filled groups are abandoned on stop)
        assert_eq!(
            log.results_seen,
            2 * log.messages_seen,
            "round {}",
            log.round
        );
        assert!(log.messages_seen >= n / 2, "round {}", log.round);
    }
    assert!(
        report.final_loss < 0.2 * l0,
        "GC training should converge: {l0} → {}",
        report.final_loss
    );
}

#[test]
fn cluster_messages_rule_runs_timing_rounds_with_frozen_theta() {
    // PCMM's plan: immediate streaming, completion at the 2n − 1-th
    // received message; the master measures timing but must not touch θ
    // (the uncoded h blocks cannot stand in for a polynomial decode)
    let n = 4;
    let plan = SchemeRegistry::cluster_plan(SchemeId::Pcmm, n, 2, n).unwrap();
    assert_eq!(plan.rule, CompletionRule::Messages { threshold: 7 });
    let mut cfg = base_config(n, 2, n, 10);
    cfg.scheduler = plan.scheduler;
    cfg.group = plan.group;
    cfg.rule = plan.rule;
    let ds = cfg.dataset.clone();
    let l0 = ds.loss(&vec![0.0; ds.d]);
    let report = run_cluster(cfg).expect("PCMM timing run");
    assert_eq!(report.rounds.len(), 10);
    for log in &report.rounds {
        assert_eq!(log.messages_seen, 7, "round {}", log.round);
        assert!(log.completion_ms > 0.0);
        assert!(log.winners.len() <= n);
    }
    assert!(
        (report.final_loss - l0).abs() < 1e-12,
        "timing rounds must leave θ frozen: {l0} vs {}",
        report.final_loss
    );
}

#[test]
fn cluster_with_pjrt_backend_runs_if_artifacts_present() {
    let dir = straggler_sched::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping PJRT cluster test: artifacts not built");
        return;
    }
    // quickstart profile: d = 64, b = 32, n = 4
    let mut cfg = base_config(4, 2, 4, 15);
    cfg.dataset = Dataset::synthesize(4, 64, 4 * 32, 5);
    cfg.use_pjrt = true;
    let ds = cfg.dataset.clone();
    let l0 = ds.loss(&vec![0.0; ds.d]);
    let report = run_cluster(cfg).expect("PJRT cluster run");
    assert!(
        report.final_loss < l0,
        "PJRT-backed training must reduce loss: {l0} → {}",
        report.final_loss
    );
}
