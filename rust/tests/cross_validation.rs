//! Cross-subsystem validation: the same quantity computed by
//! independent code paths must agree.
//!
//! 1. Monte-Carlo simulator  ↔  Theorem-1 inclusion–exclusion evaluator
//! 2. Monte-Carlo simulator  ↔  true closed-form (r = 1, shifted-exp)
//! 3. Lower bound            ↔  constructive oracle schedule
//! 4. Coded decode (PC/PCMM) ↔  uncoded gram sum on a real dataset
//! 5. PJRT artifacts         ↔  f64 CPU oracle (full-gradient level)

use straggler_sched::analysis::exact::mean_completion_r1_exp;
use straggler_sched::analysis::{collect_task_times, empirical_mean, theorem1_mean};
use straggler_sched::coded::{PcScheme, PcmmScheme};
use straggler_sched::data::Dataset;
use straggler_sched::delay::exponential::ShiftedExp;
use straggler_sched::delay::{DelayModel, Ec2LikeModel, ShiftedExponential, TruncatedGaussianModel};
use straggler_sched::harness::{evaluate, EvalPoint};
use straggler_sched::lb;
use straggler_sched::linalg::{norm2, vec_axpy};
use straggler_sched::scheduler::{oracle_schedule, SchemeId};
use straggler_sched::sim::{simulate_round, MonteCarlo};
use straggler_sched::util::rng::Rng;

#[test]
fn simulator_matches_theorem1_for_every_k_and_scheme() {
    // Theorem 1 holds for the empirical measure exactly, so the two
    // estimators must agree to float precision on the same samples.
    let model = Ec2LikeModel::new(8, 3, 0.3);
    for sched in [
        &straggler_sched::scheduler::CyclicScheduler
            as &dyn straggler_sched::scheduler::Scheduler,
        &straggler_sched::scheduler::StaircaseScheduler,
        &straggler_sched::scheduler::RandomAssignment,
    ] {
        let samples = collect_task_times(sched, &model, 8, 8, 250, 77);
        for k in 1..=8 {
            let a = theorem1_mean(&samples, k);
            let b = empirical_mean(&samples, k);
            assert!(
                (a - b).abs() < 1e-8 * (1.0 + b.abs()),
                "{} k={k}: theorem1 {a} vs direct {b}",
                sched.name()
            );
        }
    }
}

#[test]
fn simulator_matches_true_closed_form() {
    // independent ground truth: hypoexponential order statistics
    let comp = ShiftedExp::new(0.08, 6.0);
    let comm = ShiftedExp::new(0.25, 2.5);
    let model = ShiftedExponential { comp, comm };
    let mc = MonteCarlo::new(120_000, 41);
    for (n, k) in [(5, 2), (5, 5), (12, 7)] {
        let exact = mean_completion_r1_exp(n, k, comp, comm);
        let est = mc.estimate(
            &straggler_sched::scheduler::CyclicScheduler,
            &model,
            n,
            1,
            k,
        );
        assert!(
            (exact - est.mean).abs() < 5.0 * est.std_err + 2e-4,
            "n={n} k={k}: exact {exact} vs MC {} ± {}",
            est.mean,
            est.std_err
        );
    }
}

#[test]
fn lower_bound_is_achieved_by_oracle_and_respected_by_harness() {
    let model = TruncatedGaussianModel::scenario2(9, 4);
    let mut rng = Rng::seed_from_u64(10);
    let mut scratch = Vec::new();
    // constructive: oracle achieves the k-th slot order statistic
    for _ in 0..150 {
        let s = model.sample(9, 3, &mut rng);
        for k in [1usize, 4, 9] {
            let bound = lb::kth_slot_arrival(&s, k, &mut scratch);
            let to = oracle_schedule(&s, k);
            let sim = simulate_round(&to, &s, k).completion_time;
            assert!((bound - sim).abs() < 1e-12);
        }
    }
    // statistical: harness LB sits below all schemes at every point
    for r in [2usize, 5, 9] {
        let point = EvalPoint::new(9, r, 9, 4000, 8);
        let est = evaluate(&point, &model);
        let lb_mean = est
            .iter()
            .find(|e| e.scheme == SchemeId::Lb.to_string())
            .unwrap()
            .mean;
        for e in &est {
            assert!(
                lb_mean <= e.mean + 1e-9,
                "r={r}: LB {lb_mean} above {} {}",
                e.scheme,
                e.mean
            );
        }
    }
}

#[test]
fn coded_decodes_match_uncoded_sum_on_real_dataset() {
    let ds = Dataset::synthesize(6, 40, 6 * 12, 55);
    let mut rng = Rng::seed_from_u64(2);
    let theta: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
    let mut truth = vec![0.0; 40];
    for p in &ds.parts {
        vec_axpy(&mut truth, 1.0, &p.gram_matvec(&theta));
    }

    let pc = PcScheme::new(6, 3);
    let resp: Vec<_> = (0..pc.recovery_threshold())
        .map(|w| (w, pc.worker_compute(w, &ds.parts, &theta)))
        .collect();
    let mut err = pc.decode(&resp);
    vec_axpy(&mut err, -1.0, &truth);
    assert!(
        norm2(&err) / norm2(&truth) < 1e-8,
        "PC decode error {}",
        norm2(&err) / norm2(&truth)
    );

    let pcmm = PcmmScheme::new(6, 2);
    let mut resp = Vec::new();
    'outer: for j in 0..2 {
        for i in 0..6 {
            resp.push(((i, j), pcmm.worker_compute(i, j, &ds.parts, &theta)));
            if resp.len() == pcmm.recovery_threshold() {
                break 'outer;
            }
        }
    }
    let mut err = pcmm.decode(&resp);
    vec_axpy(&mut err, -1.0, &truth);
    assert!(
        norm2(&err) / norm2(&truth) < 1e-5,
        "PCMM decode error {}",
        norm2(&err) / norm2(&truth)
    );
}

#[test]
fn artifacts_full_gradient_matches_cpu_oracle() {
    let dir = straggler_sched::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rt = straggler_sched::runtime::Runtime::new(dir).unwrap();
    let meta = rt.manifest().get("quickstart", "task_gram").unwrap().clone();
    let (n, d, b) = (
        meta.dim("n").unwrap(),
        meta.dim("d").unwrap(),
        meta.dim("b").unwrap(),
    );
    let ds = Dataset::synthesize(n, d, n * b, 21);
    let theta: Vec<f64> = (0..d).map(|i| (i as f64 * 0.37).sin() * 0.2).collect();
    let theta32: Vec<f32> = theta.iter().map(|&v| v as f32).collect();

    // gradient assembled from PJRT per-task grams (the production path)
    let mut grad_rt = vec![0.0f64; d];
    for i in 0..n {
        let x32 = ds.parts[i].to_f32();
        let h = rt.task_gram("quickstart", &x32, &theta32).unwrap();
        let xy = ds.parts[i].matvec(&ds.labels[i]);
        for lane in 0..d {
            grad_rt[lane] += h[lane] as f64 - xy[lane];
        }
    }
    let scale = 2.0 / ds.padded_samples() as f64;
    grad_rt.iter_mut().for_each(|v| *v *= scale);

    let want = ds.full_gradient(&theta);
    let mut err = grad_rt.clone();
    vec_axpy(&mut err, -1.0, &want);
    assert!(
        norm2(&err) / (norm2(&want) + 1e-12) < 1e-3,
        "relative gradient error {}",
        norm2(&err) / norm2(&want)
    );
}

#[test]
fn harness_matches_standalone_monte_carlo() {
    // the coupled evaluator and the plain MonteCarlo driver implement
    // the same estimator; means must agree within joint CI
    let model = TruncatedGaussianModel::scenario1(8);
    let point = EvalPoint::new(8, 4, 8, 30_000, 101).with_schemes(&[SchemeId::Cs]);
    let a = evaluate(&point, &model).remove(0);
    let mc = MonteCarlo::new(30_000, 202);
    let b = mc.estimate(
        &straggler_sched::scheduler::CyclicScheduler,
        &model,
        8,
        4,
        8,
    );
    assert!(
        (a.mean - b.mean).abs() < 4.0 * (a.std_err + b.std_err),
        "harness {} vs mc {}",
        a.mean,
        b.mean
    );
}
