//! Figure-harness smoke tests: run every table/figure generator at
//! reduced trial counts and assert the paper's qualitative *shape*
//! (who wins, monotonicity, crossovers) plus that result files land.

use straggler_sched::harness::{self, Options};
use straggler_sched::report::Table;

fn opts(tag: &str, trials: usize) -> Options {
    let dir = std::env::temp_dir().join(format!("straggler-figs-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    Options {
        trials,
        seed: 0xF16,
        out_dir: Some(dir),
        scenario: 1,
        cluster: false,
    }
}

fn col(table: &Table, name: &str) -> Vec<f64> {
    let idx = table
        .headers
        .iter()
        .position(|h| h == name)
        .unwrap_or_else(|| panic!("no column {name}"));
    table
        .rows
        .iter()
        .map(|r| r[idx].parse::<f64>().unwrap_or(f64::NAN))
        .collect()
}

#[test]
fn table1_has_all_schemes() {
    let t = harness::table1(&opts("t1", 1)).unwrap();
    let schemes: Vec<&str> = t.rows.iter().map(|r| r[0].as_str()).collect();
    assert_eq!(schemes, vec!["CS / SS", "RA", "PC", "PCMM"]);
}

#[test]
fn fig4_scenario1_shape() {
    let o = opts("fig4", 4000);
    let t = harness::fig4(&o).unwrap();
    assert_eq!(t.rows.len(), 15); // r = 2..=16
    let (cs, ss, pc, pcmm, lb) = (
        col(&t, "CS"),
        col(&t, "SS"),
        col(&t, "PC"),
        col(&t, "PCMM"),
        col(&t, "LB"),
    );
    for i in 0..t.rows.len() {
        // paper Fig. 4: CS/SS below both coded schemes at every r
        assert!(cs[i] < pc[i], "r-row {i}: CS {} !< PC {}", cs[i], pc[i]);
        assert!(ss[i] < pc[i], "r-row {i}: SS !< PC");
        assert!(cs[i] < pcmm[i] * 1.02, "r-row {i}: CS ≪ PCMM expected");
        // LB below everything
        assert!(lb[i] <= cs[i] && lb[i] <= ss[i] && lb[i] <= pcmm[i]);
    }
    // PC worsens as r grows (paper: "average completion time of PC
    // increases with r"); compare ends
    assert!(
        pc[pc.len() - 1] > pc[1],
        "PC should degrade with r: {:?}",
        pc
    );
    // LB gap shrinks with r (paper: "reduces with r")
    let gap_first = ss[1] / lb[1];
    let gap_last = ss[ss.len() - 1] / lb[lb.len() - 1];
    assert!(gap_last < gap_first, "SS/LB gap should shrink with r");
    // files written
    let dir = o.out_dir.unwrap();
    assert!(dir.join("fig4_scenario1.csv").exists());
    assert!(dir.join("fig4_scenario1.json").exists());
}

#[test]
fn fig4_scenario2_still_orders_schemes() {
    let o = Options {
        scenario: 2,
        ..opts("fig4s2", 3000)
    };
    let t = harness::fig4(&o).unwrap();
    let (ss, pc, lb) = (col(&t, "SS"), col(&t, "PC"), col(&t, "LB"));
    for i in 0..t.rows.len() {
        assert!(ss[i] < pc[i], "row {i}");
        assert!(lb[i] <= ss[i], "row {i}");
    }
}

#[test]
fn fig5_shape_and_ra_reduction() {
    let o = opts("fig5", 4000);
    let t = harness::fig5(&o).unwrap();
    assert_eq!(t.rows.len(), 14); // r = 2..=15
    let (cs, ss, pc, pcmm, lb) = (
        col(&t, "CS"),
        col(&t, "SS"),
        col(&t, "PC"),
        col(&t, "PCMM"),
        col(&t, "LB"),
    );
    let last = t.rows.len() - 1;
    // paper Fig. 5: CS and SS significantly beat PC and PCMM
    for i in 0..=last {
        assert!(cs[i] < pc[i] && ss[i] < pc[i], "row {i}");
        assert!(cs[i] < pcmm[i] * 1.05 && ss[i] < pcmm[i] * 1.05, "row {i}");
        assert!(lb[i] <= ss[i] + 1e-9, "row {i}");
    }
    // completion time non-increasing in r for the uncoded schemes
    // (more redundancy can only help) — allow MC jitter
    assert!(cs[last] <= cs[0] * 1.02);
    assert!(ss[last] <= ss[0] * 1.02);
}

#[test]
fn fig6_shape_vs_workers() {
    let o = opts("fig6", 3000);
    let t = harness::fig6(&o).unwrap();
    assert_eq!(t.rows.len(), 6); // n = 10..=15
    let (cs, ss, ra, pc, pcmm, lb) = (
        col(&t, "CS"),
        col(&t, "SS"),
        col(&t, "RA"),
        col(&t, "PC"),
        col(&t, "PCMM"),
        col(&t, "LB"),
    );
    for i in 0..6 {
        // uncoded scheduling beats RA and both coded schemes (Fig. 6)
        assert!(cs[i] < ra[i], "row {i}: CS {} !< RA {}", cs[i], ra[i]);
        assert!(ss[i] < ra[i], "row {i}");
        assert!(cs[i] < pc[i] && ss[i] < pc[i], "row {i}");
        assert!(ss[i] < pcmm[i], "row {i}: SS {} !< PCMM {}", ss[i], pcmm[i]);
        assert!(lb[i] <= cs[i].min(ss[i]), "row {i}");
    }
    // uncoded schemes improve as workers are added (paper: "the average
    // completion time of different schemes reduce … with n")
    assert!(cs[5] < cs[0], "CS should improve with n: {cs:?}");
    assert!(ss[5] < ss[0], "SS should improve with n: {ss:?}");
    assert!(lb[5] < lb[0], "LB should improve with n: {lb:?}");
    // PCMM scales *worse* than the genie bound as n grows — its 2n−1
    // communication requirement doubles per worker added (the paper's
    // explanation for PCMM's growth in Fig. 6; see EXPERIMENTS.md for
    // the documented direction deviation under the idealized model)
    assert!(
        pcmm[5] / lb[5] > pcmm[0] / lb[0],
        "PCMM/LB ratio should grow with n: {:.4} vs {:.4}",
        pcmm[0] / lb[0],
        pcmm[5] / lb[5]
    );
}

#[test]
fn fig7_monotone_in_k_and_lb_tight_for_small_k() {
    let o = opts("fig7", 4000);
    let t = harness::fig7(&o).unwrap();
    assert_eq!(t.rows.len(), 9); // k = 2..=10
    let (cs, ss, ra, lb) = (col(&t, "CS"), col(&t, "SS"), col(&t, "RA"), col(&t, "LB"));
    for i in 1..t.rows.len() {
        // paper: "the average completion time increases with k"
        assert!(cs[i] >= cs[i - 1] - 1e-9, "CS not monotone at row {i}");
        assert!(ss[i] >= ss[i - 1] - 1e-9, "SS not monotone at row {i}");
        assert!(lb[i] >= lb[i - 1] - 1e-9, "LB not monotone at row {i}");
    }
    for i in 0..t.rows.len() {
        assert!(lb[i] <= ss[i] + 1e-9 && lb[i] <= cs[i] + 1e-9, "row {i}");
        assert!(ss[i] <= ra[i] * 1.02, "row {i}: SS {} vs RA {}", ss[i], ra[i]);
    }
    // paper: SS ≈ LB for small/medium k (k ∈ [2:6]) — within 5%
    for i in 0..4 {
        assert!(
            ss[i] / lb[i] < 1.05,
            "SS should hug LB at small k: row {i}: {} vs {}",
            ss[i],
            lb[i]
        );
    }
    // gap between schemes grows with k: RA−SS larger at k = n than k = 2
    let gap_small = ra[0] - ss[0];
    let gap_large = ra[ra.len() - 1] - ss[ss.len() - 1];
    assert!(
        gap_large > gap_small,
        "scheduling advantage should grow with k: {gap_small} vs {gap_large}"
    );
}

#[test]
fn fig8_gc_tradeoff_table() {
    let o = opts("fig8", 2500);
    let t = harness::fig8_gc(&o).unwrap();
    assert_eq!(t.rows.len(), 6); // s ∈ {1, 2, 3, 4, 6, 12}
    // s = 1 row: GC(1) ≡ CS bit-identical, so the formatted means match
    assert_eq!(t.rows[0][1], t.rows[0][2], "GC(1) must equal CS");
    // shard-seeding invariant, tested for real: CS/LB estimated *alone*
    // (same point, no GC schemes riding along) must reproduce the
    // table's CS/LB columns exactly — the coupled delay stream may not
    // depend on which schemes are evaluated together
    {
        use straggler_sched::delay::Ec2LikeModel;
        use straggler_sched::harness::{evaluate, EvalPoint, EC2_INGEST_MS};
        use straggler_sched::scheme::SchemeId;
        let n = 12;
        let model = Ec2LikeModel::new(n, o.seed ^ 0xEC2, 0.2);
        let point = EvalPoint::new(n, n, n, o.trials, o.seed)
            .with_ingest(EC2_INGEST_MS)
            .with_schemes(&[SchemeId::Cs, SchemeId::Lb]);
        let alone = evaluate(&point, &model);
        assert_eq!(Table::fmt(alone[0].mean), t.rows[0][2], "CS decoupled");
        assert_eq!(Table::fmt(alone[1].mean), t.rows[0][3], "LB decoupled");
    }
    // all means positive.  (No LB ≤ GC assertion: under the ingestion
    // model a grouped flush delivers s results per processed message,
    // which can legitimately undercut the one-result-per-message genie
    // — see EXPERIMENTS.md §Schemes.)
    let (gc, lb) = (col(&t, "GC(s)"), col(&t, "LB"));
    for i in 0..6 {
        assert!(gc[i] > 0.0 && lb[i] > 0.0, "row {i}");
    }
    let dir = o.out_dir.unwrap();
    assert!(dir.join("fig8_gc.csv").exists());
    assert!(dir.join("fig8_gc.json").exists());
}

#[test]
fn fig3_cluster_histograms() {
    let mut o = opts("fig3", 120);
    o.cluster = false; // CPU-oracle compute; still real sockets
    let (summary, hist) = harness::fig3(&o).unwrap();
    assert_eq!(summary.rows.len(), 3, "three workers");
    // comm mean > comp mean per worker (Fig. 3 headline).  The comp
    // measurement includes the *real* oracle gram compute on top of the
    // injected delay; in unoptimized debug builds that compute alone
    // exceeds the injected comm, so the ordering claim is only
    // meaningful under release codegen (the `make test` path).
    if cfg!(debug_assertions) {
        eprintln!("skipping comm>comp ordering check in debug build");
    } else {
        let comp = col(&summary, "comp mean");
        let comm = col(&summary, "comm mean");
        for w in 0..3 {
            assert!(
                comm[w] > comp[w],
                "worker {w}: comm {} !> comp {}",
                comm[w],
                comp[w]
            );
        }
    }
    // histogram table: 3 workers × 2 kinds × 24 bins
    assert_eq!(hist.rows.len(), 3 * 2 * 24);
    let dir = o.out_dir.unwrap();
    assert!(dir.join("fig3_summary.csv").exists());
    assert!(dir.join("fig3_histograms.json").exists());
}
