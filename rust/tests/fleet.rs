//! Fleet-scale regression tests — the `n = 10_000` regime the raw-speed
//! pass targets.  Guards three properties:
//!
//! 1. [`chunk_rounds`] bounds per-shard chunk memory for big fleets and
//!    leaves every paper-scale shape on the full [`BATCH_ROUNDS`];
//! 2. fleet-sized chunking is *invisible* to results — the batched
//!    engine stays bit-identical to the scalar reference even when the
//!    chunk cap kicks in;
//! 3. the flat completion kernel agrees with a naive per-task min +
//!    full-sort reference at `n = 10_000`.

use straggler_sched::delay::{DelayModel, ShiftedExponential};
use straggler_sched::scheduler::{CyclicScheduler, Scheduler};
use straggler_sched::sim::{
    chunk_rounds, completion_from_arrivals, slot_arrivals_batch, FlatTasks, MonteCarlo,
    BATCH_ROUNDS, MAX_CHUNK_SLOTS,
};
use straggler_sched::util::rng::Rng;

#[test]
fn chunk_rounds_caps_fleet_memory_and_keeps_paper_shapes() {
    // every shape the paper's figures use keeps the full chunk size
    for (n, r) in [(1usize, 1usize), (8, 4), (16, 16), (32, 32), (100, 20)] {
        assert_eq!(chunk_rounds(n, r), BATCH_ROUNDS, "n={n} r={r}");
    }
    // fleet shapes scale the chunk down under the slot budget
    for (n, r) in [(10_000usize, 4usize), (5_000, 2), (10_000, 1)] {
        let c = chunk_rounds(n, r);
        assert!((1..BATCH_ROUNDS).contains(&c), "n={n} r={r}: {c}");
        assert!(c * n * r <= MAX_CHUNK_SLOTS, "n={n} r={r}: {c}");
    }
}

#[test]
fn chunked_fleet_estimates_bit_identical_to_scalar() {
    // n·r = 10_000 > the 8192-slot full-chunk knee, so the batched
    // engine runs sub-BATCH_ROUNDS chunks here — and must still
    // reproduce the scalar reference bit-for-bit (chunking only splits
    // the round-sequential delay stream, never reorders it)
    let (n, r, k) = (5_000usize, 2usize, 4_000usize);
    assert!(chunk_rounds(n, r) < BATCH_ROUNDS);
    let model = ShiftedExponential::new(0.05, 4.0, 0.2, 2.0);
    let mc = MonteCarlo {
        trials: 40,
        seed: 321,
        threads: 2,
    };
    let schemes: Vec<&dyn Scheduler> = vec![&CyclicScheduler];
    let batched = mc.estimate_coupled(&schemes, &model, n, r, k);
    let scalar = mc.estimate_coupled_scalar(&schemes, &model, n, r, k);
    assert_eq!(batched[0].mean.to_bits(), scalar[0].mean.to_bits());
    assert_eq!(batched[0].p50.to_bits(), scalar[0].p50.to_bits());
    assert_eq!(batched[0].p95.to_bits(), scalar[0].p95.to_bits());
    assert_eq!(batched[0].min.to_bits(), scalar[0].min.to_bits());
    assert_eq!(batched[0].max.to_bits(), scalar[0].max.to_bits());
}

#[test]
fn fleet_completion_kernel_matches_naive_reference_at_n_10_000() {
    let (n, r, k) = (10_000usize, 4usize, 9_000usize);
    let model = ShiftedExponential::new(0.05, 4.0, 0.2, 2.0);
    let mut rng = Rng::seed_from_u64(7);
    let batch = model.sample_batch(2, n, r, &mut rng);
    let mut arrivals = Vec::new();
    slot_arrivals_batch(&batch, &mut arrivals);
    let to = CyclicScheduler.schedule(n, r, &mut Rng::seed_from_u64(0));
    let flat = FlatTasks::new(&to);
    let stride = n * r;
    let mut task_times = Vec::new();
    for b in 0..batch.rounds {
        let slice = &arrivals[b * stride..(b + 1) * stride];
        let fast = completion_from_arrivals(&flat, slice, k, &mut task_times);
        // naive reference: per-task first arrival, then a full sort
        let mut mins = vec![f64::INFINITY; n];
        for (slot, &task) in flat.tasks().iter().enumerate() {
            if slice[slot] < mins[task] {
                mins[task] = slice[slot];
            }
        }
        mins.sort_by(f64::total_cmp);
        assert_eq!(fast.to_bits(), mins[k - 1].to_bits(), "round {b}");
    }
}
