//! End-to-end latency anatomy: run a real in-process fleet with a
//! **deterministic** injected delay profile (`DelayModelKind::Fixed` —
//! known ground truth per phase, one worker slowed by a known factor)
//! and assert that the master's v5 wire-timestamp decomposition
//! recovers the injected compute/comm split per worker, that the
//! anomaly watchdog fires on exactly the injected straggler, and that
//! the `/debug/flight` endpoint serves the recorder ring mid-run.
//!
//! The geometry (CS, `r = 1`, `k = n`) puts every worker on the
//! critical path each round, so the straggler's frames always arrive
//! inside the collect window and feed the anatomy (stale frames are
//! dropped before observation — see the sync loop).
//!
//! Tolerances are one-sided where the substrate guarantees a bound
//! (`spin_sleep` never undershoots, so measured compute ≥ injected
//! compute) and ratio-based elsewhere: the clock-offset estimator may
//! legitimately absorb up to half a worker's min RTT into the network
//! phase, so absolute floors stay below `inj_comm / 2`.

use std::io::{Read as _, Write as _};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use straggler_sched::adaptive::PolicyKind;
use straggler_sched::coordinator::{run_cluster, ClusterConfig, IoMode};
use straggler_sched::data::Dataset;
use straggler_sched::delay::DelayModelKind;
use straggler_sched::scheme::{SchemeId, SchemeRegistry};
use straggler_sched::telemetry::{metrics as tm, MetricsConfig};
use straggler_sched::util::json::Json;

/// Injected ground truth, generous enough to dominate scheduling noise.
const COMP_MS: f64 = 2.0;
const COMM_MS: f64 = 0.5;
const STRAGGLER: usize = 2;
const FACTOR: f64 = 8.0;

/// Parse a `/debug/flight` HTTP response into its event list:
/// `(kind, worker, phase_idx)` per event (`phase_idx` only meaningful
/// for anomaly events — `vals[0]` on the wire).
fn flight_events(dump: &str) -> Vec<(String, f64, f64)> {
    let body = dump
        .split_once("\r\n\r\n")
        .map(|(_, b)| b)
        .expect("flight response has no body");
    let doc = Json::parse(body.trim()).expect("flight dump must be valid JSON");
    let events = match doc.get("events") {
        Some(Json::Arr(evs)) => evs.clone(),
        other => panic!("flight dump events: {other:?}"),
    };
    events
        .iter()
        .map(|ev| {
            let kind = ev
                .get("kind")
                .and_then(Json::as_str)
                .expect("event kind")
                .to_string();
            let worker = ev.get("worker").and_then(Json::as_f64).expect("event worker");
            let phase_idx = match ev.get("vals") {
                Some(Json::Arr(vals)) => vals[0].as_f64().expect("vals[0]"),
                other => panic!("event vals: {other:?}"),
            };
            (kind, worker, phase_idx)
        })
        .collect()
}

/// Does the dump carry an anomaly event on `worker`'s compute or
/// network phase — the two the injection actually perturbs?
fn has_injected_anomaly(dump: &str, worker: usize) -> bool {
    flight_events(dump).iter().any(|(kind, w, phase)| {
        kind == "anomaly" && *w as usize == worker && (*phase == 0.0 || *phase == 2.0)
    })
}

/// Poll `GET /debug/flight` against the master's scrape listener until
/// a dump carrying the straggler's anomaly appears (or the run ends).
/// The listener only exists while `run_cluster` is live, so early
/// connects fail and are retried; the last successful dump is kept
/// either way.
fn poll_flight(addr: String, stop: Arc<AtomicBool>) -> Option<String> {
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut last: Option<String> = None;
    while Instant::now() < deadline {
        let done = stop.load(Ordering::Relaxed);
        if let Ok(mut s) = TcpStream::connect(&addr) {
            let _ = s.set_read_timeout(Some(Duration::from_secs(5)));
            if s.write_all(b"GET /debug/flight HTTP/1.1\r\n\r\n").is_ok() {
                let mut resp = Vec::new();
                let mut buf = [0u8; 65536];
                loop {
                    match s.read(&mut buf) {
                        Ok(0) => break,
                        Ok(k) => resp.extend_from_slice(&buf[..k]),
                        Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                        Err(_) => break,
                    }
                }
                let text = String::from_utf8_lossy(&resp).into_owned();
                if text.starts_with("HTTP/1.1 200") {
                    let hit = has_injected_anomaly(&text, STRAGGLER);
                    last = Some(text);
                    if hit {
                        return last;
                    }
                }
            }
        }
        if done {
            // one post-shutdown attempt already happened above; the
            // listener died with the master, so whatever we saw last
            // is the final word
            return last;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    last
}

#[test]
fn injected_straggler_phases_are_recovered_and_flagged() {
    let (n, r, k, rounds) = (4usize, 1usize, 4usize, 60usize);

    // the registry is process-global and cumulative — assert on deltas
    let anomalies_before = tm::ANOMALY_TOTAL.get();

    // reserve a port for the scrape listener so the poller knows the
    // address before `run_cluster` binds it (same trick the parity
    // harness uses for the master's own listener)
    let metrics_addr = {
        let probe = TcpListener::bind("127.0.0.1:0").expect("probe bind");
        probe.local_addr().expect("probe addr").to_string()
    };
    let stop = Arc::new(AtomicBool::new(false));
    let poller = {
        let addr = metrics_addr.clone();
        let stop = stop.clone();
        std::thread::spawn(move || poll_flight(addr, stop))
    };

    let report = run_cluster(ClusterConfig {
        n,
        r,
        k,
        eta: 0.05,
        rounds,
        profile: "quickstart".into(),
        plan: SchemeRegistry::cluster_plan(SchemeId::Cs, n, r, k)
            .unwrap_or_else(|e| panic!("CS plan: {e:#}")),
        policy: PolicyKind::Static,
        staleness: 1,
        dataset: Dataset::synthesize(n, 16, n * 8, 42),
        inject: Some(DelayModelKind::Fixed {
            comp_ms: COMP_MS,
            comm_ms: COMM_MS,
            straggler: Some(STRAGGLER),
            factor: FACTOR,
        }),
        seed: 7,
        use_pjrt: false,
        artifact_dir: None,
        loss_every: 1,
        listen: None,
        spawn_workers: true,
        io: IoMode::Reactor,
        metrics: MetricsConfig {
            addr: Some(metrics_addr),
            log: None,
            ..MetricsConfig::default()
        },
    })
    .unwrap_or_else(|e| panic!("anatomy master run: {e:#}"));
    stop.store(true, Ordering::Relaxed);

    assert_eq!(report.rounds.len(), rounds);
    assert!(report.final_loss.is_finite());

    // ---- phase recovery from the report's per-worker attribution ----------
    let attr = &report.spans.attribution;
    assert_eq!(attr.len(), n);
    let strag = attr
        .iter()
        .find(|a| a.worker == STRAGGLER)
        .expect("straggler attribution row");
    assert!(
        strag.phase_frames > 0,
        "the straggler's frames must reach the anatomy"
    );
    // compute: spin_sleep never undershoots, so the measured phase is
    // bounded below by the injection
    let strag_comp = strag.phase_mean_ms[0];
    assert!(
        strag_comp >= COMP_MS * FACTOR - 0.1,
        "straggler compute {strag_comp:.3} ms < injected {:.1} ms",
        COMP_MS * FACTOR
    );
    let other_comp: Vec<f64> = attr
        .iter()
        .filter(|a| a.worker != STRAGGLER)
        .map(|a| a.phase_mean_ms[0])
        .collect();
    for (i, &c) in other_comp.iter().enumerate() {
        assert!(
            c >= COMP_MS - 0.1,
            "non-straggler {i} compute {c:.3} ms < injected {COMP_MS:.1} ms"
        );
    }
    let other_comp_mean = other_comp.iter().sum::<f64>() / other_comp.len() as f64;
    assert!(
        strag_comp > 2.5 * other_comp_mean,
        "injected ×{FACTOR} compute factor not recovered: \
         straggler {strag_comp:.3} ms vs fleet {other_comp_mean:.3} ms"
    );

    // network: the comm injection happens after the send stamp, so it
    // lands in the measured network phase.  The offset estimator can
    // absorb at most ~min-RTT/2 ≈ inj_comm/2, hence the floor below
    // COMM_MS × FACTOR / 2.
    let strag_net = strag.phase_mean_ms[2];
    assert!(
        strag_net >= 1.5,
        "straggler network {strag_net:.3} ms lost the {:.1} ms comm injection",
        COMM_MS * FACTOR
    );
    let other_net_mean = attr
        .iter()
        .filter(|a| a.worker != STRAGGLER)
        .map(|a| a.phase_mean_ms[2])
        .sum::<f64>()
        / (n - 1) as f64;
    assert!(
        strag_net > 1.5 * other_net_mean,
        "straggler network {strag_net:.3} ms vs fleet {other_net_mean:.3} ms"
    );
    // the recovered compute/comm split stays near the injected 16:4
    // (estimator slack allows up to ~16:2)
    let split = strag_comp / strag_net;
    assert!(
        (1.5..=14.0).contains(&split),
        "straggler compute/comm split {split:.2} strayed from the injected \
         {:.1}",
        (COMP_MS * FACTOR) / (COMM_MS * FACTOR)
    );
    // queue: enqueue → send inside the delivery handoff — must be a
    // sane small duration, never negative (saturating by construction)
    for a in attr {
        assert!(
            a.phase_mean_ms[1].is_finite() && a.phase_mean_ms[1] >= 0.0,
            "worker {} queue phase: {}",
            a.worker,
            a.phase_mean_ms[1]
        );
    }

    // ---- the same split reaches the measured trace ------------------------
    let strag_trace_comm = report.trace.comm_ms(STRAGGLER);
    assert!(!strag_trace_comm.is_empty());
    let trace_net_mean =
        strag_trace_comm.iter().sum::<f64>() / strag_trace_comm.len() as f64;
    assert!(
        trace_net_mean >= 1.5,
        "trace comm for the straggler lost the injection: {trace_net_mean:.3} ms"
    );

    // ---- anomaly watchdog -------------------------------------------------
    assert!(
        tm::ANOMALY_TOTAL.get() > anomalies_before,
        "the ×{FACTOR} straggler must trip the anomaly detector"
    );

    // ---- /debug/flight served the ring mid-run ----------------------------
    let dump = poller
        .join()
        .expect("flight poller panicked")
        .expect("/debug/flight was never served during the run");
    let events = flight_events(&dump);
    assert!(!events.is_empty(), "flight ring empty mid-run");
    let mut straggler_anomalies = 0usize;
    for (kind, worker, phase_idx) in &events {
        match kind.as_str() {
            "phase" => assert!((0.0..n as f64).contains(worker)),
            "anomaly" => {
                // exactness on the phases the injection perturbs: a
                // compute or network anomaly may only name the injected
                // straggler (queue/dwell are scheduling-noise phases
                // the injection leaves alone, so they are not pinned)
                if *phase_idx == 0.0 || *phase_idx == 2.0 {
                    assert_eq!(
                        *worker as usize, STRAGGLER,
                        "anomaly flagged worker {worker}, injected straggler \
                         is {STRAGGLER}"
                    );
                    straggler_anomalies += 1;
                }
            }
            other => panic!("unexpected flight event kind {other:?}"),
        }
    }
    assert!(
        straggler_anomalies > 0,
        "the dump that ended the poll must carry the straggler's anomaly"
    );
}
