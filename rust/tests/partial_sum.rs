//! Property tests for the protocol-v3 duplicate-safe partial-sum
//! aggregation (`coordinator::aggregate`): GC(s) wire blocks must
//! reconstruct the exact full gradient under arbitrary arrival order,
//! duplicate flushes and any group size `s` — with a θ trajectory
//! **bit-identical** to `s = 1`.
//!
//! The h-vectors are drawn integer-valued, so every grouping of the
//! sums is exact in f64 and bit-identity is a set property (no task
//! dropped, none double-counted), not a floating-point accident — the
//! live wire adds only f32 rounding on top of the same set semantics.
//!
//! No `proptest` crate in the offline build; this drives the same
//! in-tree seeded-case harness as `tests/proptests.rs`.

use straggler_sched::coordinator::{Offer, RoundAggregator};
use straggler_sched::data::Dataset;
use straggler_sched::gd::UncodedMaster;
use straggler_sched::util::rng::Rng;

/// Run `prop` over `cases` seeded cases; panic with the failing seed.
fn forall(name: &str, cases: u64, mut prop: impl FnMut(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::seed_from_u64(0x5A6E ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            eprintln!("property {name} FAILED at case seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

/// Integer-valued per-task h vectors: exactly representable, so sums
/// are associative in f64.
fn integer_h_table(rng: &mut Rng, n: usize, d: usize) -> Vec<Vec<f64>> {
    (0..n)
        .map(|_| (0..d).map(|_| rng.below(17) as f64 - 8.0).collect())
        .collect()
}

fn range_sum(h: &[Vec<f64>], lo: usize, hi: usize, d: usize) -> Vec<f64> {
    let mut sum = vec![0.0; d];
    for t in lo..hi {
        for (acc, v) in sum.iter_mut().zip(&h[t]) {
            *acc += v;
        }
    }
    sum
}

/// Decompose worker `w`'s cyclic row (r = n) into its aligned v3 flush
/// ranges: flush after task `t` when `(t+1) % s == 0`, at contiguity
/// breaks (the mod-n wrap), and at the row end — exactly the worker
/// loop in `coordinator/worker.rs`.
fn aligned_flush_ranges(w: usize, n: usize, s: usize) -> Vec<(usize, usize)> {
    aligned_flush_ranges_rows(&(0..n).map(|j| (w + j) % n).collect::<Vec<_>>(), s)
}

/// Same decomposition for an arbitrary row and per-worker flush size —
/// the shape the adaptive `load` policy produces (each worker has its
/// own `s_i`, a divisor of the canonical block).
fn aligned_flush_ranges_rows(row: &[usize], s: usize) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let mut start = 0usize;
    for (slot, &t) in row.iter().enumerate() {
        let last = slot + 1 == row.len();
        let flush = last || (t + 1) % s == 0 || row[slot + 1] != t + 1;
        if flush {
            ranges.push((row[start], t + 1));
            start = slot + 1;
        }
    }
    ranges
}

#[test]
fn prop_gc_partial_sums_reconstruct_exact_full_gradient() {
    forall("gc reconstruction", 150, |rng| {
        let n = 2 + rng.below(11); // 2..=12 tasks, r = n (the GC regime)
        let d = 1 + rng.below(6);
        let k = n;
        let h = integer_h_table(rng, n, d);
        let full_sum = range_sum(&h, 0, n, d);

        // the s = 1 reference winners/sum: all n tasks in task order
        for s in 1..=n {
            // every worker's aligned flush decomposition …
            let mut offers: Vec<(usize, usize)> = Vec::new();
            for w in 0..n {
                offers.extend(aligned_flush_ranges(w, n, s));
            }
            // … plus duplicate flushes from lagging stragglers …
            for _ in 0..rng.below(1 + n) {
                let dup = offers[rng.below(offers.len())];
                offers.push(dup);
            }
            // … in arbitrary arrival order
            rng.shuffle(&mut offers);

            let mut agg = RoundAggregator::new(n, d, s, k);
            for &(lo, hi) in &offers {
                let tasks: Vec<usize> = (lo..hi).collect();
                let verdict = agg.offer(&tasks, &range_sum(&h, lo, hi, d));
                assert_ne!(verdict, Offer::Malformed, "range {lo}..{hi} at s={s}");
            }
            assert!(
                agg.complete(),
                "full offer set must cover all {n} tasks at s = {s}"
            );
            let (winners, sum) = agg.finish();
            assert_eq!(winners, (0..n).collect::<Vec<_>>(), "s = {s}");
            for lane in 0..d {
                assert_eq!(
                    sum[lane].to_bits(),
                    full_sum[lane].to_bits(),
                    "s = {s} lane {lane}: {} vs {}",
                    sum[lane],
                    full_sum[lane]
                );
            }
        }
    });
}

#[test]
fn prop_theta_trajectory_bit_identical_across_s_and_arrival_order() {
    forall("theta bit-identity", 60, |rng| {
        let n = 2 + rng.below(9); // 2..=10
        let d = 1 + rng.below(5);
        let ds = Dataset::synthesize(n, d, n * 4, rng.next_u64());
        let eta = 0.05;
        let rounds = 3;

        // reference: s = 1, in-order singleton delivery
        let mut reference = UncodedMaster::new(&ds, eta, n);
        // candidates: a few group sizes, each with its own shuffled,
        // duplicated arrival stream per round
        let sizes: Vec<usize> = (2..=n).filter(|&s| s <= 4 || s == n).collect();
        let mut candidates: Vec<(usize, UncodedMaster)> = sizes
            .iter()
            .map(|&s| (s, UncodedMaster::new(&ds, eta, n)))
            .collect();
        let mut rng_step = Rng::seed_from_u64(1);

        for round in 0..rounds {
            let h = integer_h_table(rng, n, d);
            // reference round
            let mut agg = RoundAggregator::new(n, d, 1, n);
            for t in 0..n {
                agg.offer(&[t], &range_sum(&h, t, t + 1, d));
            }
            let (w_ref, sum_ref) = agg.finish();
            reference.apply_aggregate(w_ref, sum_ref, n, ds.padded_samples(), &mut rng_step);

            for (s, master) in candidates.iter_mut() {
                let mut offers: Vec<(usize, usize)> = Vec::new();
                for w in 0..n {
                    offers.extend(aligned_flush_ranges(w, n, *s));
                }
                for _ in 0..rng.below(1 + n) {
                    let dup = offers[rng.below(offers.len())];
                    offers.push(dup);
                }
                rng.shuffle(&mut offers);
                let mut agg = RoundAggregator::new(n, d, *s, n);
                for &(lo, hi) in &offers {
                    let tasks: Vec<usize> = (lo..hi).collect();
                    agg.offer(&tasks, &range_sum(&h, lo, hi, d));
                }
                assert!(agg.complete(), "s = {s} round {round}");
                let (w, sum) = agg.finish();
                let mut rng_s = Rng::seed_from_u64(1); // no reshuffle drawn anyway
                master.apply_aggregate(w, sum, n, ds.padded_samples(), &mut rng_s);
                for i in 0..d {
                    assert_eq!(
                        master.theta[i].to_bits(),
                        reference.theta[i].to_bits(),
                        "θ[{i}] diverged at s = {s}, round {round}"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_replanned_flush_sizes_never_double_count_theta() {
    // the adaptive `load` policy's safety property: per-worker flush
    // sizes may be re-split at EVERY round boundary (each worker's s_i
    // a divisor of the canonical block, as the policy guarantees) and
    // the θ trajectory must stay bit-identical to an oracle s = 1
    // in-order run on integer blocks — no task dropped, none counted
    // twice, across rounds, duplicates and arbitrary arrival order
    forall("replan theta bit-identity", 50, |rng| {
        let n = 3 + rng.below(8); // 3..=10, r = n cyclic
        let d = 1 + rng.below(4);
        let canonical = 2 + rng.below(n - 1); // 2..=n
        let divisors: Vec<usize> = (1..=canonical).filter(|s| canonical % s == 0).collect();
        let ds = Dataset::synthesize(n, d, n * 4, rng.next_u64());
        let eta = 0.05;
        let rounds = 5;

        let mut reference = UncodedMaster::new(&ds, eta, n);
        let mut replanned = UncodedMaster::new(&ds, eta, n);

        for round in 0..rounds {
            let h = integer_h_table(rng, n, d);

            // oracle: one worker, s = 1, in task order
            let mut agg = RoundAggregator::new(n, d, 1, n);
            for t in 0..n {
                agg.offer(&[t], &range_sum(&h, t, t + 1, d));
            }
            let (w_ref, sum_ref) = agg.finish();
            let mut rng_step = Rng::seed_from_u64(1);
            reference.apply_aggregate(w_ref, sum_ref, n, ds.padded_samples(), &mut rng_step);

            // replanned round: fresh per-worker sizes drawn THIS round
            let sizes: Vec<usize> =
                (0..n).map(|_| divisors[rng.below(divisors.len())]).collect();
            let mut offers: Vec<(usize, usize)> = Vec::new();
            for w in 0..n {
                let row: Vec<usize> = (0..n).map(|j| (w + j) % n).collect();
                offers.extend(aligned_flush_ranges_rows(&row, sizes[w]));
            }
            for _ in 0..rng.below(1 + n) {
                let dup = offers[rng.below(offers.len())];
                offers.push(dup);
            }
            rng.shuffle(&mut offers);
            let mut agg = RoundAggregator::new(n, d, canonical, n);
            for &(lo, hi) in &offers {
                let tasks: Vec<usize> = (lo..hi).collect();
                let verdict = agg.offer(&tasks, &range_sum(&h, lo, hi, d));
                assert_ne!(
                    verdict,
                    Offer::Malformed,
                    "round {round}: {lo}..{hi} with sizes {sizes:?} (canonical {canonical})"
                );
            }
            assert!(agg.complete(), "round {round} covers all tasks");
            let (w, sum) = agg.finish();
            let mut rng_step = Rng::seed_from_u64(1);
            replanned.apply_aggregate(w, sum, n, ds.padded_samples(), &mut rng_step);

            for i in 0..d {
                assert_eq!(
                    replanned.theta[i].to_bits(),
                    reference.theta[i].to_bits(),
                    "θ[{i}] diverged at round {round} (sizes {sizes:?}, canonical {canonical})"
                );
            }
        }
    });
}

#[test]
fn prop_no_double_count_under_adversarial_ranges() {
    // beyond worker-shaped streams: throw arbitrary valid in-block
    // ranges (any sub-range of any canonical block) at the aggregator
    // in any order; whatever it accepts, the finished sum must equal
    // the per-task sum over exactly the reported winners — no task
    // counted twice, none smuggled in
    forall("no double count", 200, |rng| {
        let n = 2 + rng.below(15); // 2..=16
        let s = 1 + rng.below(n);
        let d = 1 + rng.below(4);
        let k = 1 + rng.below(n);
        let h = integer_h_table(rng, n, d);

        let mut agg = RoundAggregator::new(n, d, s, k);
        for _ in 0..rng.below(40) {
            // a random sub-range of a random canonical block
            let block = rng.below(n.div_ceil(s));
            let b_lo = block * s;
            let b_hi = (b_lo + s).min(n);
            let lo = b_lo + rng.below(b_hi - b_lo);
            let hi = lo + 1 + rng.below(b_hi - lo);
            let tasks: Vec<usize> = (lo..hi).collect();
            let verdict = agg.offer(&tasks, &range_sum(&h, lo, hi, d));
            assert_ne!(verdict, Offer::Malformed, "{lo}..{hi} (block {block})");
        }
        let distinct = agg.distinct();
        let (winners, sum) = agg.finish();
        assert_eq!(winners.len(), distinct);
        let mut sorted = winners.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), winners.len(), "winners must be distinct");
        let mut want = vec![0.0; d];
        for &t in winners {
            for (acc, v) in want.iter_mut().zip(&h[t]) {
                *acc += v;
            }
        }
        for lane in 0..d {
            assert_eq!(
                sum[lane].to_bits(),
                want[lane].to_bits(),
                "lane {lane}: {} vs {}",
                sum[lane],
                want[lane]
            );
        }
    });
}

#[test]
fn prop_reused_aggregator_matches_fresh_per_round() {
    // the live master builds ONE aggregator per run and resets it at
    // each round boundary (warm slot arena, recycled free-list); an
    // arbitrary multi-round adversarial offer stream through the reused
    // arena must match per-round fresh aggregators verdict-for-verdict
    // and bit-for-bit in the finished sums
    forall("reuse ≡ fresh", 80, |rng| {
        let n = 2 + rng.below(15); // 2..=16
        let s = 1 + rng.below(n);
        let d = 1 + rng.below(4);
        let k = 1 + rng.below(n);
        let mut reused = RoundAggregator::new(n, d, s, k);
        for round in 0..4 {
            reused.reset();
            let h = integer_h_table(rng, n, d);
            let mut fresh = RoundAggregator::new(n, d, s, k);
            for _ in 0..rng.below(40) {
                let block = rng.below(n.div_ceil(s));
                let b_lo = block * s;
                let b_hi = (b_lo + s).min(n);
                let lo = b_lo + rng.below(b_hi - b_lo);
                let hi = lo + 1 + rng.below(b_hi - lo);
                let tasks: Vec<usize> = (lo..hi).collect();
                let sum = range_sum(&h, lo, hi, d);
                assert_eq!(
                    reused.offer(&tasks, &sum),
                    fresh.offer(&tasks, &sum),
                    "round {round}: verdicts diverged on {lo}..{hi}"
                );
            }
            assert_eq!(reused.distinct(), fresh.distinct(), "round {round}");
            let (w_reused, sum_reused) = {
                let (w, t) = reused.finish();
                (w.to_vec(), t.to_vec())
            };
            let (w_fresh, sum_fresh) = fresh.finish();
            assert_eq!(w_reused, w_fresh, "round {round}");
            for lane in 0..d {
                assert_eq!(
                    sum_reused[lane].to_bits(),
                    sum_fresh[lane].to_bits(),
                    "round {round} lane {lane}"
                );
            }
        }
    });
}
