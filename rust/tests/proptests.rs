//! Randomized property tests over the crate's core invariants.
//!
//! The offline build has no `proptest` crate, so this file drives a
//! small in-tree property harness: each property is checked over a
//! couple of hundred randomized configurations drawn from a seeded RNG;
//! failures report the seed so the exact case can be replayed.

use straggler_sched::coded::{DecodeCache, PcScheme, PcmmScheme};
use straggler_sched::coordinator::Msg;
use straggler_sched::delay::{
    DelayModel, DelaySample, Ec2LikeModel, ShiftedExponential, TruncatedGaussianModel,
    WorkerCorrelated,
};
use straggler_sched::lb::kth_slot_arrival;
use straggler_sched::scheduler::{
    oracle_schedule, CyclicScheduler, RandomAssignment, Scheduler, StaircaseScheduler,
};
use straggler_sched::sim::{simulate_round, task_arrival_times};
use straggler_sched::util::json::Json;
use straggler_sched::util::rng::Rng;

/// Run `prop` over `cases` seeded cases; panic with the failing seed.
fn forall(name: &str, cases: u64, mut prop: impl FnMut(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::seed_from_u64(0xFACADE ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            eprintln!("property {name} FAILED at case seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

fn random_model(rng: &mut Rng, n: usize) -> Box<dyn DelayModel> {
    match rng.below(4) {
        0 => Box::new(TruncatedGaussianModel::scenario1(n)),
        1 => Box::new(TruncatedGaussianModel::scenario2(n, rng.next_u64())),
        2 => Box::new(Ec2LikeModel::new(n, rng.next_u64(), 0.3)),
        _ => Box::new(WorkerCorrelated::new(
            ShiftedExponential::new(0.05 + rng.f64() * 0.2, 1.0 + rng.f64() * 8.0, 0.1, 2.0),
            rng.f64(),
        )),
    }
}

fn random_scheduler(rng: &mut Rng) -> Box<dyn Scheduler> {
    match rng.below(3) {
        0 => Box::new(CyclicScheduler),
        1 => Box::new(StaircaseScheduler),
        _ => Box::new(RandomAssignment),
    }
}

#[test]
fn prop_to_matrices_are_valid_and_distinct() {
    forall("to-matrix invariants", 300, |rng| {
        let n = 1 + rng.below(16);
        let r = 1 + rng.below(n);
        let sched = random_scheduler(rng);
        let to = sched.schedule(n, r, rng);
        assert_eq!(to.n(), n);
        assert_eq!(to.r(), r);
        assert!(to.rows_distinct(), "{} n={n} r={r}", sched.name());
        // coverage conservation: total slots = n·r
        assert_eq!(to.coverage().iter().sum::<usize>(), n * r);
    });
}

#[test]
fn prop_completion_monotone_in_k() {
    forall("t_C monotone in k", 150, |rng| {
        let n = 2 + rng.below(10);
        let r = 1 + rng.below(n);
        let model = random_model(rng, n);
        let sched = random_scheduler(rng);
        let to = sched.schedule(n, r, rng);
        let s = model.sample(n, r, rng);
        let max_k = to
            .coverage()
            .iter()
            .filter(|&&c| c > 0)
            .count();
        let mut last = 0.0;
        for k in 1..=max_k {
            let t = simulate_round(&to, &s, k).completion_time;
            assert!(t >= last - 1e-12, "k={k}");
            last = t;
        }
    });
}

#[test]
fn prop_lb_below_any_schedule_every_realization() {
    forall("LB ≤ t_C(T) pointwise", 150, |rng| {
        let n = 2 + rng.below(10);
        let r = 1 + rng.below(n);
        let model = random_model(rng, n);
        let sched = random_scheduler(rng);
        let to = sched.schedule(n, r, rng);
        let s = model.sample(n, r, rng);
        let mut scratch = Vec::new();
        let max_k = to.coverage().iter().filter(|&&c| c > 0).count();
        for k in 1..=max_k {
            let bound = kth_slot_arrival(&s, k, &mut scratch);
            let t = simulate_round(&to, &s, k).completion_time;
            assert!(bound <= t + 1e-12, "k={k}: {bound} > {t}");
        }
    });
}

#[test]
fn prop_oracle_schedule_achieves_kth_order_stat() {
    forall("oracle achieves LB", 150, |rng| {
        let n = 2 + rng.below(8);
        let r = 1 + rng.below(n);
        let model = random_model(rng, n);
        let s = model.sample(n, r, rng);
        let k = 1 + rng.below(n.min(n * r));
        let to = oracle_schedule(&s, k);
        assert!(to.rows_distinct());
        let mut scratch = Vec::new();
        let want = kth_slot_arrival(&s, k, &mut scratch);
        let got = simulate_round(&to, &s, k).completion_time;
        assert!((want - got).abs() < 1e-9);
    });
}

#[test]
fn prop_task_arrivals_lower_bound_every_slot() {
    // t_j = min over placements; every placement's arrival ≥ t_j
    forall("task arrival is a min", 100, |rng| {
        let n = 2 + rng.below(8);
        let r = 1 + rng.below(n);
        let model = random_model(rng, n);
        let sched = random_scheduler(rng);
        let to = sched.schedule(n, r, rng);
        let s = model.sample(n, r, rng);
        let t = task_arrival_times(&to, &s);
        for task in 0..n {
            for (i, j) in to.placements(task) {
                let arrival = s.slot_arrival(i, j);
                assert!(arrival >= t[task] - 1e-12);
            }
        }
    });
}

#[test]
fn prop_coded_thresholds_within_bounds() {
    forall("coded thresholds", 200, |rng| {
        let n = 2 + rng.below(14);
        let r = 2 + rng.below(n.saturating_sub(1).max(1));
        if r > n {
            return;
        }
        let pc = PcScheme::new(n, r);
        assert!(pc.recovery_threshold() >= 1);
        assert!(
            pc.recovery_threshold() <= n.div_ceil(r) * 2,
            "PC threshold 2⌈n/r⌉−1 bound"
        );
        if n * r >= 2 * n - 1 {
            let pcmm = PcmmScheme::new(n, r);
            assert_eq!(pcmm.recovery_threshold(), 2 * n - 1);
            // PCMM completion uses slots: must be ≥ LB at k=n and ≥ 0
            let model = random_model(rng, n);
            let s = model.sample(n, r, rng);
            let mut scratch = Vec::new();
            let t = pcmm.completion_time(&s, &mut scratch);
            let lbv = kth_slot_arrival(&s, n, &mut scratch);
            assert!(t >= lbv - 1e-12, "PCMM below k=n LB");
        }
    });
}

#[test]
fn prop_pc_encode_decode_random_shapes() {
    forall("PC decode exact", 25, |rng| {
        let n = 2 + rng.below(6);
        let r = 2.min(n) + rng.below(n.saturating_sub(1).max(1));
        let r = r.min(n).max(2);
        if r > n {
            return;
        }
        let d = 3 + rng.below(8);
        let b = 2 + rng.below(5);
        let parts: Vec<_> = (0..n)
            .map(|_| straggler_sched::linalg::Mat::from_fn(d, b, |_, _| rng.normal()))
            .collect();
        let theta: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let pc = PcScheme::new(n, r);
        let resp: Vec<_> = (0..pc.recovery_threshold())
            .map(|w| (w, pc.worker_compute(w, &parts, &theta)))
            .collect();
        let decoded = pc.decode(&resp);
        let mut want = vec![0.0; d];
        for p in &parts {
            straggler_sched::linalg::vec_axpy(&mut want, 1.0, &p.gram_matvec(&theta));
        }
        for lane in 0..d {
            assert!(
                (decoded[lane] - want[lane]).abs() < 1e-5 * (1.0 + want[lane].abs()),
                "n={n} r={r} lane {lane}"
            );
        }
    });
}

fn shuffle(xs: &mut [usize], rng: &mut Rng) {
    for i in (1..xs.len()).rev() {
        let j = rng.below(i + 1);
        xs.swap(i, j);
    }
}

/// The decode-cache contract: for any shape, responder subset, arrival
/// order and payload, the cached decode is bit-identical to the fresh
/// weight decode — a cache hit may never change a single output bit.
#[test]
fn prop_cached_decode_bit_identical_to_fresh() {
    forall("cached decode ≡ fresh", 60, |rng| {
        let n = 2 + rng.below(7);
        let r = (2 + rng.below(n - 1)).min(n);
        let d = 1 + rng.below(6);

        // PC: random threshold-sized worker subset, two arrival orders
        let pc = PcScheme::new(n, r);
        let m = pc.recovery_threshold();
        let mut workers: Vec<usize> = (0..n).collect();
        shuffle(&mut workers, rng);
        let order_a: Vec<usize> = workers[..m].to_vec();
        let mut order_b = order_a.clone();
        shuffle(&mut order_b, rng);
        let data: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..d).map(|_| rng.normal()).collect())
            .collect();
        let resp = |ord: &[usize]| -> Vec<(usize, Vec<f64>)> {
            ord.iter().map(|&w| (w, data[w].clone())).collect()
        };
        let fresh = pc.decode(&resp(&order_a));
        let mut cache = DecodeCache::with_default_cap();
        let c1 = pc.decode_cached(&resp(&order_a), &mut cache); // miss: builds
        let c2 = pc.decode_cached(&resp(&order_b), &mut cache); // hit: cached weights
        for lane in 0..d {
            assert_eq!(fresh[lane].to_bits(), c1[lane].to_bits(), "PC n={n} r={r} lane {lane}");
            assert_eq!(
                fresh[lane].to_bits(),
                c2[lane].to_bits(),
                "PC n={n} r={r} lane {lane} (cache hit)"
            );
        }
        assert_eq!((cache.stats().hits, cache.stats().misses), (1, 1));

        // PCMM: random (2n−1)-slot subset of the n·r evaluation slots
        let pcmm = PcmmScheme::new(n, r);
        let mm = pcmm.recovery_threshold();
        let mut slots: Vec<usize> = (0..n * r).collect();
        shuffle(&mut slots, rng);
        let order_a: Vec<usize> = slots[..mm].to_vec();
        let mut order_b = order_a.clone();
        shuffle(&mut order_b, rng);
        let sdata: Vec<Vec<f64>> = (0..n * r)
            .map(|_| (0..d).map(|_| rng.normal()).collect())
            .collect();
        let mresp = |ord: &[usize]| -> Vec<((usize, usize), Vec<f64>)> {
            ord.iter().map(|&s| ((s / r, s % r), sdata[s].clone())).collect()
        };
        let fresh = pcmm.decode(&mresp(&order_a));
        let mut cache = DecodeCache::with_default_cap();
        let c1 = pcmm.decode_cached(&mresp(&order_a), &mut cache);
        let c2 = pcmm.decode_cached(&mresp(&order_b), &mut cache);
        for lane in 0..d {
            assert_eq!(fresh[lane].to_bits(), c1[lane].to_bits(), "PCMM n={n} r={r} lane {lane}");
            assert_eq!(
                fresh[lane].to_bits(),
                c2[lane].to_bits(),
                "PCMM n={n} r={r} lane {lane} (cache hit)"
            );
        }
        assert_eq!((cache.stats().hits, cache.stats().misses), (1, 1));
    });
}

#[test]
fn prop_delay_samples_positive_and_shaped() {
    forall("delay samples valid", 200, |rng| {
        let n = 1 + rng.below(16);
        let r = 1 + rng.below(n);
        let model = random_model(rng, n);
        let s = model.sample(n, r, rng);
        assert_eq!(s.n, n);
        assert_eq!(s.r, r);
        for i in 0..n {
            for j in 0..r {
                assert!(s.comp(i, j) > 0.0 && s.comp(i, j).is_finite());
                assert!(s.comm(i, j) > 0.0 && s.comm(i, j).is_finite());
            }
        }
    });
}

#[test]
fn prop_protocol_roundtrip_random_messages() {
    forall("protocol roundtrip", 300, |rng| {
        let msg = match rng.below(6) {
            0 => Msg::Welcome {
                proto: rng.next_u64() as u32,
                worker_id: rng.next_u64() as u32,
                profile: format!("p{}", rng.below(100)),
            },
            1 => Msg::LoadData {
                d: rng.below(50) as u32 + 1,
                b: rng.below(50) as u32 + 1,
                batches: (0..rng.below(4))
                    .map(|i| (i as u32, (0..rng.below(64)).map(|_| rng.normal() as f32).collect()))
                    .collect(),
            },
            2 => Msg::Assign {
                round: rng.next_u64() as u32,
                version: rng.next_u64() as u32,
                theta: (0..rng.below(128)).map(|_| rng.normal() as f32).collect(),
                tasks: (0..rng.below(16)).map(|_| rng.below(99) as u32).collect(),
                batches: (0..rng.below(16)).map(|_| rng.below(99) as u32).collect(),
                group: 1 + rng.below(8) as u32,
                align: rng.below(2) == 0,
            },
            3 => Msg::Result {
                round: rng.next_u64() as u32,
                version: rng.next_u64() as u32,
                worker_id: rng.below(64) as u32,
                tasks: (1..=1 + rng.below(4)).map(|_| rng.below(64) as u32).collect(),
                comp_us: rng.next_u64(),
                send_ts_us: rng.next_u64(),
                h: (0..rng.below(256)).map(|_| rng.normal() as f32).collect(),
            },
            4 => Msg::Stop {
                round: rng.next_u64() as u32,
            },
            _ => Msg::Shutdown,
        };
        let decoded = Msg::decode(&msg.encode()).expect("roundtrip");
        assert_eq!(decoded, msg);
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.normal() * 1e3).round() / 8.0),
            3 => Json::Str(
                (0..rng.below(12))
                    .map(|_| char::from(b'a' + rng.below(26) as u8))
                    .collect(),
            ),
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall("json roundtrip", 400, |rng| {
        let v = random_json(rng, 3);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
    });
}

#[test]
fn prop_gc1_bit_identical_to_cs_and_gc_groups_defer() {
    // the scheme layer's grouped multi-message family must degenerate
    // to CS exactly at s = 1 (both idealized and ingestion dynamics),
    // for every shape and delay model
    use straggler_sched::scheme::{RoundView, SchemeEvaluator as _, SchemeId, SchemeRegistry};
    use straggler_sched::sim::slot_arrivals_batch;
    forall("GC(1) ≡ CS pointwise", 60, |rng| {
        let n = 2 + rng.below(10);
        let r = 1 + rng.below(n);
        let k = 1 + rng.below(n);
        let model = random_model(rng, n);
        let batch = model.sample_batch(6, n, r, rng);
        let mut arrivals = Vec::new();
        slot_arrivals_batch(&batch, &mut arrivals);
        let stride = batch.stride();
        let mut sched_a = Rng::seed_from_u64(0);
        let mut sched_b = Rng::seed_from_u64(0);
        let mut cs = SchemeRegistry::build(SchemeId::Cs).prepare(n, r, k, &mut sched_a);
        let mut gc1 = SchemeRegistry::build(SchemeId::Gc(1)).prepare(n, r, k, &mut sched_b);
        for b in 0..batch.rounds {
            let view = RoundView {
                arrivals: &arrivals[b * stride..(b + 1) * stride],
                comp: batch.comp_round(b),
                comm: batch.comm_round(b),
            };
            let a = cs.completion(&view, &mut sched_a);
            let g = gc1.completion(&view, &mut sched_b);
            assert_eq!(a.to_bits(), g.to_bits(), "n={n} r={r} k={k} round {b}");
            let ai = cs.completion_ingest(&view, 0.15, &mut sched_a);
            let gi = gc1.completion_ingest(&view, 0.15, &mut sched_b);
            assert_eq!(ai.to_bits(), gi.to_bits(), "ingest n={n} r={r} k={k} round {b}");
        }
    });
}

#[test]
fn prop_cs_ss_beat_or_match_ra_at_full_load() {
    // statistical dominance at r = n (paper Figs. 5–7): averaged over a
    // coupled batch, designed schedules beat random assignment
    forall("CS/SS ≤ RA (batch mean)", 12, |rng| {
        let n = 4 + rng.below(8);
        let model = random_model(rng, n);
        let trials = 1500;
        let (mut cs_tot, mut ss_tot, mut ra_tot) = (0.0, 0.0, 0.0);
        let cs = CyclicScheduler.schedule(n, n, rng);
        let ss = StaircaseScheduler.schedule(n, n, rng);
        for _ in 0..trials {
            let s = model.sample(n, n, rng);
            let ra = RandomAssignment.schedule(n, n, rng);
            cs_tot += simulate_round(&cs, &s, n).completion_time;
            ss_tot += simulate_round(&ss, &s, n).completion_time;
            ra_tot += simulate_round(&ra, &s, n).completion_time;
        }
        // 3% slack for MC noise
        assert!(cs_tot <= ra_tot * 1.03, "CS {cs_tot} vs RA {ra_tot} (n={n})");
        assert!(ss_tot <= ra_tot * 1.03, "SS {ss_tot} vs RA {ra_tot} (n={n})");
    });
}
