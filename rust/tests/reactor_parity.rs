//! Bit-identity cross-check between the master's two data planes
//! (`IoMode::Threads` vs `IoMode::Reactor`).
//!
//! The real fleet is timing-dependent (delivery threads race), so this
//! harness replaces the workers with a **scripted fleet**: it connects
//! `n` logical workers, answers every `Assign` with honest grouped
//! flushes computed from the Assign's own θ, and ships *every* Result
//! frame — for all logical workers — over **connection 0** in a fixed
//! order.  The master never validates a frame's `worker_id` against its
//! arrival connection, so both data planes observe the identical total
//! program order, and everything downstream of ingestion (aggregation,
//! θ updates, round accounting) must be **bit-identical**.  Wall-clock
//! fields (`completion_ms`; the dwell/comm measurements) are the only
//! legitimate difference and are excluded from the comparison.

use std::collections::{HashMap, VecDeque};
use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use straggler_sched::adaptive::PolicyKind;
use straggler_sched::coordinator::framebuf::encode_result_into;
use straggler_sched::coordinator::{
    now_us, run_cluster, ClusterConfig, ClusterReport, IoMode, Msg, RoundLog,
};
use straggler_sched::data::Dataset;
use straggler_sched::linalg::{vec_axpy, Mat};
use straggler_sched::scheme::{SchemeId, SchemeRegistry};
use straggler_sched::telemetry::MetricsConfig;

/// One decoded `Assign`, queued per logical worker by the fleet driver.
struct Assign {
    round: u32,
    version: u32,
    theta: Vec<f32>,
    tasks: Vec<u32>,
    batches: Vec<u32>,
    group: u32,
    align: bool,
}

fn connect_retry(addr: &str) -> TcpStream {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return s,
            Err(e) => {
                assert!(
                    Instant::now() < deadline,
                    "could not reach master at {addr}: {e}"
                );
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

/// Emulate `run_worker`'s grouped-flush loop for one Assign (without the
/// stop watermark — the script always completes its row, which is
/// deterministic in both modes; the master drops the surplus as stale
/// or duplicate identically).  Frames carry fixed `comp_us` and fixed
/// v5 phase stamps so nothing wall-clock-dependent reaches the wire:
/// the master's latency anatomy sees garbage offsets, which is exactly
/// the point — telemetry must stay inert no matter what the stamps say.
fn flush_frames(w: usize, a: &Assign, parts: &HashMap<u32, Mat>) -> Vec<Vec<u8>> {
    let group = (a.group.max(1) as usize).min(a.tasks.len().max(1));
    let theta64: Vec<f64> = a.theta.iter().map(|&v| v as f64).collect();
    let mut frames = Vec::new();
    let mut buf_tasks: Vec<u32> = Vec::new();
    let mut buf_sum: Vec<f64> = Vec::new();
    for (slot, (&task, &batch)) in a.tasks.iter().zip(&a.batches).enumerate() {
        let part = parts
            .get(&batch)
            .unwrap_or_else(|| panic!("worker {w}: batch {batch} was never shipped"));
        let h = part.gram_matvec(&theta64);
        buf_tasks.push(task);
        if buf_sum.is_empty() {
            buf_sum = h;
        } else {
            vec_axpy(&mut buf_sum, 1.0, &h);
        }
        let last_slot = slot + 1 == a.tasks.len();
        let flush = if a.align {
            last_slot
                || (task as usize + 1) % group == 0
                || a.tasks[slot + 1] != task.wrapping_add(1)
        } else {
            last_slot || buf_tasks.len() == group
        };
        if !flush {
            continue;
        }
        let comp_us = 1_000 + w as u64;
        let mut frame = Vec::new();
        encode_result_into(
            &mut frame,
            a.round,
            a.version,
            w as u32,
            &buf_tasks,
            comp_us,
            0,       // comp_start_us
            comp_us, // comp_end_us
            comp_us, // enqueue_us
            comp_us, // send_ts_us
            &buf_sum,
        );
        frames.push(frame);
        buf_tasks.clear();
        buf_sum.clear();
    }
    frames
}

/// The scripted fleet: pin worker ids by sequential handshakes, then
/// answer each round's Assigns (all n, in worker order) with flushes
/// sent exclusively on connection 0.
fn scripted_fleet(addr: String, n: usize, rounds: usize) {
    // sequential connect + Welcome read pins accept order = worker id;
    // the v5 handshake then expects a Hello back (the master's clock
    // exchange) before it moves on to the next accept
    let mut conns: Vec<TcpStream> = Vec::new();
    for i in 0..n {
        let stream = connect_retry(&addr);
        stream.set_nodelay(true).expect("nodelay");
        let mut rd = stream.try_clone().expect("clone");
        match Msg::read_from(&mut rd).expect("welcome") {
            Msg::Welcome { worker_id, .. } => {
                assert_eq!(worker_id as usize, i, "accept order must pin worker ids")
            }
            other => panic!("expected Welcome, got {other:?}"),
        }
        let mut wr = stream.try_clone().expect("clone");
        Msg::Hello {
            worker_id: i as u32,
            ts_us: now_us(),
        }
        .write_to(&mut wr)
        .expect("hello");
        conns.push(stream);
    }
    // every conn gets its LoadData next; keep each worker's batches
    let mut parts: Vec<HashMap<u32, Mat>> = Vec::with_capacity(n);
    for c in &conns {
        let mut rd = c.try_clone().expect("clone");
        match Msg::read_from(&mut rd).expect("load data") {
            Msg::LoadData { d, batches, .. } => {
                let dim = d as usize;
                parts.push(
                    batches
                        .into_iter()
                        .map(|(id, x)| {
                            let b = x.len() / dim;
                            (id, Mat::from_fn(dim, b, |i, j| x[i * b + j] as f64))
                        })
                        .collect(),
                );
            }
            other => panic!("expected LoadData, got {other:?}"),
        }
    }

    // reader thread per conn: forward Assigns, swallow Stop/Shutdown
    let (tx, rx) = mpsc::channel::<(usize, Assign)>();
    for (i, c) in conns.iter().enumerate() {
        let mut rd = c.try_clone().expect("clone");
        let tx = tx.clone();
        std::thread::spawn(move || loop {
            match Msg::read_from(&mut rd) {
                Ok(Msg::Assign {
                    round,
                    version,
                    theta,
                    tasks,
                    batches,
                    group,
                    align,
                    .. // issue_us: the clock exchange is telemetry-only
                }) => {
                    if tx
                        .send((
                            i,
                            Assign {
                                round,
                                version,
                                theta,
                                tasks,
                                batches,
                                group,
                                align,
                            },
                        ))
                        .is_err()
                    {
                        return;
                    }
                }
                Ok(Msg::Stop { .. }) => {}
                Ok(Msg::Shutdown) | Err(_) => return,
                Ok(other) => panic!("fleet conn {i}: unexpected {other:?}"),
            }
        });
    }

    // drive the rounds: wait for all n Assigns of the round (the pump
    // may interleave later rounds' Assigns — queue them), then send
    // every worker's flushes in worker order on conn 0
    let mut writer0 = conns[0].try_clone().expect("clone");
    let mut queues: Vec<VecDeque<Assign>> = (0..n).map(|_| VecDeque::new()).collect();
    for round in 0..rounds {
        for w in 0..n {
            while queues[w].is_empty() {
                let (i, a) = rx
                    .recv_timeout(Duration::from_secs(30))
                    .expect("fleet starved waiting for Assign");
                queues[i].push_back(a);
            }
            let a = queues[w].pop_front().expect("queued assign");
            assert_eq!(
                a.round as usize, round,
                "worker {w}: assigns must arrive in round order"
            );
            for frame in flush_frames(w, &a, &parts[w]) {
                writer0.write_all(&frame).expect("fleet write");
            }
        }
        writer0.flush().expect("fleet flush");
    }
}

/// One master run against the scripted fleet.
fn run_mode(
    io: IoMode,
    scheme: SchemeId,
    n: usize,
    r: usize,
    k: usize,
    staleness: usize,
    metrics: MetricsConfig,
) -> ClusterReport {
    let rounds = 10usize;
    // learn a free port, release it, and hand it to the master — the
    // fleet needs the address before `run_cluster` binds
    let addr = {
        let probe = TcpListener::bind("127.0.0.1:0").expect("probe bind");
        probe.local_addr().expect("probe addr").to_string()
    };
    let fleet = {
        let addr = addr.clone();
        std::thread::spawn(move || scripted_fleet(addr, n, rounds))
    };
    let report = run_cluster(ClusterConfig {
        n,
        r,
        k,
        eta: 0.05,
        rounds,
        profile: "quickstart".into(),
        plan: SchemeRegistry::cluster_plan(scheme, n, r, k)
            .unwrap_or_else(|e| panic!("{scheme} plan: {e:#}")),
        policy: PolicyKind::Static,
        staleness,
        dataset: Dataset::synthesize(n, 16, n * 8, 42),
        inject: None,
        seed: 7,
        use_pjrt: false,
        artifact_dir: None,
        loss_every: 1,
        listen: Some(addr),
        spawn_workers: false,
        io,
        metrics,
    })
    .unwrap_or_else(|e| panic!("{io} master run: {e:#}"));
    fleet.join().expect("scripted fleet panicked");
    report
}

/// Everything in a `RoundLog` except wall-clock completion must match.
fn assert_logs_identical(scheme: SchemeId, a: &[RoundLog], b: &[RoundLog]) {
    assert_eq!(a.len(), b.len(), "{scheme}: round count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.round, y.round, "{scheme}");
        assert_eq!(x.winners, y.winners, "{scheme} round {}", x.round);
        assert_eq!(
            x.results_seen, y.results_seen,
            "{scheme} round {}",
            x.round
        );
        assert_eq!(
            x.messages_seen, y.messages_seen,
            "{scheme} round {}",
            x.round
        );
        assert_eq!(x.wire_bytes, y.wire_bytes, "{scheme} round {}", x.round);
        assert_eq!(x.replanned, y.replanned, "{scheme} round {}", x.round);
        let (lx, ly) = (x.loss, y.loss);
        assert_eq!(
            lx.map(f64::to_bits),
            ly.map(f64::to_bits),
            "{scheme} round {}: loss must be bit-identical",
            x.round
        );
    }
}

fn assert_parity(scheme: SchemeId, n: usize, r: usize, k: usize, staleness: usize) {
    let threads = run_mode(
        IoMode::Threads,
        scheme,
        n,
        r,
        k,
        staleness,
        MetricsConfig::default(),
    );
    let reactor = run_mode(
        IoMode::Reactor,
        scheme,
        n,
        r,
        k,
        staleness,
        MetricsConfig::default(),
    );
    assert_eq!(
        threads.final_theta.len(),
        reactor.final_theta.len(),
        "{scheme}: θ dimension"
    );
    for (i, (a, b)) in threads
        .final_theta
        .iter()
        .zip(&reactor.final_theta)
        .enumerate()
    {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{scheme} (S = {staleness}): θ[{i}] diverged: {a} vs {b}"
        );
    }
    assert_eq!(
        threads.final_loss.to_bits(),
        reactor.final_loss.to_bits(),
        "{scheme}: final loss"
    );
    assert_logs_identical(scheme, &threads.rounds, &reactor.rounds);
    // both planes measured every frame they handed the loop
    assert_eq!(
        threads.ingest.frames, reactor.ingest.frames,
        "{scheme}: ingest frame count"
    );
    assert!(threads.ingest.frames > 0 && reactor.ingest.frames > 0);
}

#[test]
fn cs_sync_is_bit_identical_across_io_modes() {
    assert_parity(SchemeId::Cs, 4, 2, 4, 1);
}

#[test]
fn cs_staleness2_is_bit_identical_across_io_modes() {
    assert_parity(SchemeId::Cs, 4, 2, 4, 2);
}

#[test]
fn gc2_sync_is_bit_identical_across_io_modes() {
    assert_parity(SchemeId::Gc(2), 4, 4, 4, 1);
}

#[test]
fn gc2_staleness2_is_bit_identical_across_io_modes() {
    assert_parity(SchemeId::Gc(2), 4, 4, 4, 2);
}

#[test]
fn pc_sync_is_bit_identical_across_io_modes() {
    // coded wire: one full-row flush per worker, Messages-rule stop at
    // the recovery threshold, master-side Lagrange decode
    assert_parity(SchemeId::Pc, 4, 2, 4, 1);
}

/// Telemetry must be *inert*: the same scripted fleet with the metrics
/// exporter fully armed (live `/metrics` listener on an ephemeral port
/// plus the per-round JSONL snapshot log) must produce bit-identical
/// θ / loss / round logs versus a plain run.  The exporter consumes no
/// RNG and never reorders frames, so any divergence here is a bug in
/// the instrumentation, not noise.
fn assert_telemetry_inert(io: IoMode, scheme: SchemeId, staleness: usize) {
    let (n, r, k) = (4usize, 2usize, 4usize);
    let plain = run_mode(io, scheme, n, r, k, staleness, MetricsConfig::default());
    let log_path = std::env::temp_dir().join(format!(
        "straggler_inert_{}_{io}_s{staleness}.jsonl",
        std::process::id()
    ));
    let armed = MetricsConfig {
        addr: Some("127.0.0.1:0".into()),
        log: Some(log_path.display().to_string()),
        ..MetricsConfig::default()
    };
    let telemetry = run_mode(io, scheme, n, r, k, staleness, armed);
    for (i, (a, b)) in plain
        .final_theta
        .iter()
        .zip(&telemetry.final_theta)
        .enumerate()
    {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{scheme} {io} (S = {staleness}): telemetry perturbed θ[{i}]: {a} vs {b}"
        );
    }
    assert_eq!(
        plain.final_loss.to_bits(),
        telemetry.final_loss.to_bits(),
        "{scheme} {io}: telemetry perturbed the final loss"
    );
    assert_logs_identical(scheme, &plain.rounds, &telemetry.rounds);
    assert_eq!(
        plain.ingest.frames, telemetry.ingest.frames,
        "{scheme} {io}: telemetry changed the ingest frame count"
    );
    // the armed run really exported: one snapshot per round (plus the
    // final teardown snapshot), each line carrying the core series
    let log = std::fs::read_to_string(&log_path).expect("metrics log was not written");
    assert!(
        log.lines().count() > plain.rounds.len(),
        "expected at least one JSONL snapshot per round, got {} lines",
        log.lines().count()
    );
    assert!(
        log.contains("straggler_master_frames_total"),
        "snapshot lines must carry the registry series"
    );
    let _ = std::fs::remove_file(&log_path);
}

#[test]
fn telemetry_is_inert_on_threads_plane() {
    assert_telemetry_inert(IoMode::Threads, SchemeId::Cs, 1);
}

#[test]
fn telemetry_is_inert_on_reactor_plane() {
    assert_telemetry_inert(IoMode::Reactor, SchemeId::Cs, 1);
}

#[test]
fn telemetry_is_inert_on_pipelined_reactor() {
    assert_telemetry_inert(IoMode::Reactor, SchemeId::Cs, 2);
}
