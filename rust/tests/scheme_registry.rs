//! Scheme-layer acceptance tests: the registry's applicability matrix
//! against paper Table I, bit-identity of every registry-dispatched
//! scheme vs the pre-refactor kernels for a fixed
//! `(trials, threads, seed)` triple, and the GC(s) family's contract
//! (`GC(1)` ≡ CS; grouping trades arrival lateness for message count).

use straggler_sched::adaptive::{run_policy_rounds, PerRound, PolicyKind, PolicyRunConfig};
use straggler_sched::coded::{PcScheme, PcmmScheme};
use straggler_sched::delay::{DelayModel, TruncatedGaussianModel};
use straggler_sched::harness::{evaluate, EvalPoint};
use straggler_sched::lb::kth_slot_arrival;
use straggler_sched::scheme::{SchemeId, SchemeRegistry};
use straggler_sched::sim::{shard_rngs, CompletionEstimate, MonteCarlo, BATCH_ROUNDS};
use straggler_sched::util::stats::{RunningStats, StreamingQuantiles};

#[test]
fn applicability_matrix_matches_paper_table1() {
    use SchemeId::*;
    let n = 8;
    let cases: &[(SchemeId, usize, usize, bool)] = &[
        // (id, r, k, applicable?)
        (Cs, 1, 1, true),
        (Cs, 8, 8, true),
        (Ss, 1, 8, true),
        (Ss, 8, 3, true),
        // RA requires the full dataset at every worker: r = n
        (Ra, 8, 8, true),
        (Ra, 8, 3, true),
        (Ra, 7, 8, false),
        (Ra, 1, 1, false),
        // PC/PCMM: r ≥ 2 and full-gradient target k = n only
        (Pc, 1, 8, false),
        (Pc, 2, 8, true),
        (Pc, 8, 8, true),
        (Pc, 8, 5, false),
        (Pcmm, 1, 8, false),
        (Pcmm, 2, 8, true),
        (Pcmm, 2, 7, false),
        // the genie bound applies everywhere
        (Lb, 1, 1, true),
        (Lb, 8, 8, true),
        // GC group bounded by the row length (and never zero)
        (Gc(0), 8, 8, false),
        (Gc(1), 1, 4, true),
        (Gc(2), 1, 8, false),
        (Gc(2), 2, 5, true),
        (Gc(8), 8, 8, true),
        (Gc(9), 8, 8, false),
        // heterogeneous flush sizes: both ramp endpoints in [1, r]
        (GcHet(4, 1), 4, 8, true),
        (GcHet(1, 4), 4, 8, true),
        (GcHet(2, 2), 2, 5, true),
        (GcHet(5, 1), 4, 8, false),
        (GcHet(1, 5), 4, 8, false),
        (GcHet(0, 2), 4, 8, false),
    ];
    for &(id, r, k, want) in cases {
        assert_eq!(
            SchemeRegistry::applicable(id, n, r, k),
            want,
            "{id} at (n={n}, r={r}, k={k})"
        );
    }
}

/// Replay one single-shard delay stream exactly as the registry engine
/// sees it (same `shard_rngs`, same chunking) and fold a reference
/// per-round kernel into streaming stats.
fn reference_stream(
    model: &dyn DelayModel,
    n: usize,
    r: usize,
    trials: usize,
    seed: u64,
    mut kernel: impl FnMut(&straggler_sched::delay::DelaySample) -> f64,
) -> (RunningStats, StreamingQuantiles) {
    let (mut rng, _sched) = shard_rngs(seed, 0);
    let mut stats = RunningStats::new();
    let mut quantiles = StreamingQuantiles::new();
    let mut done = 0usize;
    while done < trials {
        let chunk = BATCH_ROUNDS.min(trials - done);
        let batch = model.sample_batch(chunk, n, r, &mut rng);
        for b in 0..chunk {
            let t = kernel(&batch.round_sample(b));
            stats.push(t);
            quantiles.push(t);
        }
        done += chunk;
    }
    (stats, quantiles)
}

fn estimate_one(
    id: SchemeId,
    model: &dyn DelayModel,
    n: usize,
    r: usize,
    k: usize,
    trials: usize,
    seed: u64,
) -> CompletionEstimate {
    let mut point = EvalPoint::new(n, r, k, trials, seed).with_schemes(&[id]);
    point.threads = 1; // single shard → directly replayable stream
    evaluate(&point, model).remove(0)
}

#[test]
fn registry_pc_pcmm_lb_bit_identical_to_prerefactor_kernels() {
    // the coded timing models and the genie bound used to be computed
    // by hand-rolled kernels (coded::{pc,pcmm}::completion_time,
    // lb::kth_slot_arrival); the registry-dispatched evaluators must
    // reproduce them to the last bit on the identical delay stream
    let (n, r, k, trials, seed) = (9usize, 3usize, 9usize, 700usize, 41u64);
    let model = TruncatedGaussianModel::scenario2(n, 6);

    let pc = PcScheme::new(n, r);
    let mut scratch = Vec::new();
    let (stats, q) = reference_stream(&model, n, r, trials, seed, |s| {
        pc.completion_time(s, &mut scratch)
    });
    let want = CompletionEstimate::from_streams("PC".into(), n, r, k, &stats, &q);
    let got = estimate_one(SchemeId::Pc, &model, n, r, k, trials, seed);
    assert_eq!(got.mean.to_bits(), want.mean.to_bits(), "PC mean");
    assert_eq!(got.p95.to_bits(), want.p95.to_bits(), "PC p95");

    let pcmm = PcmmScheme::new(n, r);
    let mut scratch = Vec::new();
    let (stats, q) = reference_stream(&model, n, r, trials, seed, |s| {
        pcmm.completion_time(s, &mut scratch)
    });
    let want = CompletionEstimate::from_streams("PCMM".into(), n, r, k, &stats, &q);
    let got = estimate_one(SchemeId::Pcmm, &model, n, r, k, trials, seed);
    assert_eq!(got.mean.to_bits(), want.mean.to_bits(), "PCMM mean");
    assert_eq!(got.p95.to_bits(), want.p95.to_bits(), "PCMM p95");

    let mut scratch = Vec::new();
    let (stats, q) = reference_stream(&model, n, r, trials, seed, |s| {
        kth_slot_arrival(s, k, &mut scratch)
    });
    let want = CompletionEstimate::from_streams("LB".into(), n, r, k, &stats, &q);
    let got = estimate_one(SchemeId::Lb, &model, n, r, k, trials, seed);
    assert_eq!(got.mean.to_bits(), want.mean.to_bits(), "LB mean");
    assert_eq!(got.p95.to_bits(), want.p95.to_bits(), "LB p95");
}

#[test]
fn registry_coupled_estimates_bit_identical_to_monte_carlo_engine() {
    // harness (registry dispatch) and MonteCarlo (scheduler adapters)
    // now share one shard loop; a coupled CS+SS+RA evaluation must
    // agree to the last bit for a fixed (trials, threads, seed)
    use straggler_sched::scheduler::{
        CyclicScheduler, RandomAssignment, Scheduler, StaircaseScheduler,
    };
    let model = TruncatedGaussianModel::scenario1(8);
    let (n, r, k, trials, seed) = (8usize, 8usize, 8usize, 2500usize, 99u64);
    let mut point = EvalPoint::new(n, r, k, trials, seed)
        .with_schemes(&[SchemeId::Cs, SchemeId::Ss, SchemeId::Ra]);
    point.threads = 3;
    let harness = evaluate(&point, &model);
    let mc = MonteCarlo {
        trials,
        seed,
        threads: 3,
    };
    let scheds: Vec<&dyn Scheduler> =
        vec![&CyclicScheduler, &StaircaseScheduler, &RandomAssignment];
    let plain = mc.estimate_coupled(&scheds, &model, n, r, k);
    for (a, b) in harness.iter().zip(&plain) {
        assert_eq!(a.mean.to_bits(), b.mean.to_bits(), "{} mean", a.scheme);
        assert_eq!(a.p50.to_bits(), b.p50.to_bits(), "{} p50", a.scheme);
        assert_eq!(a.p95.to_bits(), b.p95.to_bits(), "{} p95", a.scheme);
        assert_eq!(a.min.to_bits(), b.min.to_bits(), "{} min", a.scheme);
        assert_eq!(a.max.to_bits(), b.max.to_bits(), "{} max", a.scheme);
    }
}

#[test]
fn gc1_bit_identical_to_cs_in_coupled_evaluation() {
    // GC(1) must degenerate to CS exactly — same delay stream, same
    // per-round completion times, hence identical streamed statistics,
    // under both the idealized and the ingestion dynamics
    let model = TruncatedGaussianModel::scenario1(10);
    for ingest in [0.0, 0.15] {
        let point = EvalPoint::new(10, 5, 10, 3000, 7)
            .with_ingest(ingest)
            .with_schemes(&[SchemeId::Cs, SchemeId::Gc(1)]);
        let est = evaluate(&point, &model);
        let (cs, gc) = (&est[0], &est[1]);
        assert_eq!(cs.mean.to_bits(), gc.mean.to_bits(), "ingest {ingest} mean");
        assert_eq!(cs.p50.to_bits(), gc.p50.to_bits(), "ingest {ingest} p50");
        assert_eq!(cs.p95.to_bits(), gc.p95.to_bits(), "ingest {ingest} p95");
        assert_eq!(cs.min.to_bits(), gc.min.to_bits(), "ingest {ingest} min");
        assert_eq!(cs.max.to_bits(), gc.max.to_bits(), "ingest {ingest} max");
    }
}

#[test]
fn gc_grouping_trades_lateness_for_messages() {
    let model = TruncatedGaussianModel::scenario1(8);
    let (n, r, k, trials, seed) = (8usize, 8usize, 8usize, 4000usize, 13u64);

    // idealized dynamics: holding results until the flush slot can only
    // hurt on average (later prefix sums, same comm marginal)
    let point = EvalPoint::new(n, r, k, trials, seed)
        .with_schemes(&[SchemeId::Gc(1), SchemeId::Gc(4)]);
    let est = evaluate(&point, &model);
    assert!(
        est[1].mean > est[0].mean,
        "GC(4) {} should be slower than GC(1) {} at ingest 0",
        est[1].mean,
        est[0].mean
    );

    // heavy ingestion: GC(1) queues ≥ k messages at 1 ms each, while
    // GC(8)'s one-message-per-worker flood finishes after a handful
    let point = EvalPoint::new(n, r, k, trials, seed)
        .with_ingest(1.0)
        .with_schemes(&[SchemeId::Gc(1), SchemeId::Gc(8)]);
    let est = evaluate(&point, &model);
    assert!(
        est[1].mean < est[0].mean,
        "GC(8) {} should beat GC(1) {} at 1 ms ingest",
        est[1].mean,
        est[0].mean
    );
}

#[test]
fn gch_runs_coupled_and_degenerates_to_uniform_gc() {
    // the heterogeneity-aware family dispatches through the same
    // registry/evaluator path: a flat ramp is bit-identical to GC(s),
    // and a real ramp produces a sane coupled estimate
    let model = TruncatedGaussianModel::scenario1(8);
    for ingest in [0.0, 0.15] {
        let point = EvalPoint::new(8, 4, 8, 1500, 3)
            .with_ingest(ingest)
            .with_schemes(&[SchemeId::Gc(3), SchemeId::GcHet(3, 3), SchemeId::GcHet(4, 1)]);
        let est = evaluate(&point, &model);
        assert_eq!(
            est[0].mean.to_bits(),
            est[1].mean.to_bits(),
            "GCH(s,s) ≡ GC(s), ingest {ingest}"
        );
        assert_eq!(est[0].p95.to_bits(), est[1].p95.to_bits());
        assert!(est[2].mean.is_finite() && est[2].mean > 0.0);
    }
}

#[test]
fn lb_statistically_bounds_gc_family() {
    // caveat: the §V genie bound models one result per message, while a
    // GC flush can deliver a whole group on a single (possibly cheap)
    // comm draw — so LB ≤ GC(s) is NOT a per-realization theorem (see
    // EXPERIMENTS.md §Schemes).  In the paper's delay regimes the
    // computation-prefix penalty dominates and the bound holds in the
    // mean; assert that with joint-CI slack.
    let model = TruncatedGaussianModel::scenario2(9, 3);
    let point = EvalPoint::new(9, 6, 9, 3000, 5).with_schemes(&[
        SchemeId::Lb,
        SchemeId::Gc(2),
        SchemeId::Gc(3),
        SchemeId::Gc(6),
    ]);
    let est = evaluate(&point, &model);
    let lb = &est[0];
    for e in &est[1..] {
        assert!(
            lb.mean <= e.mean + 3.0 * (lb.std_err + e.std_err),
            "LB {} above {} {}",
            lb.mean,
            e.scheme,
            e.mean
        );
    }
}

#[test]
fn static_policy_bit_identical_to_registry_path_for_every_scheme() {
    // the adaptive subsystem's ground rule: `--policy static` IS the
    // pre-adaptive engine — same shard-0 RNG streams, same chunked
    // sampling, same kernels — for every scheme the registry knows,
    // under both the idealized and the ingestion dynamics
    let (n, trials, seed) = (8usize, 700usize, 23u64);
    let model = TruncatedGaussianModel::scenario2(n, 9);
    let cases: &[(SchemeId, usize, usize)] = &[
        (SchemeId::Cs, 4, 6),
        (SchemeId::Ss, 4, 6),
        (SchemeId::Ra, 8, 5), // randomized redraws must consume rng_sched identically
        (SchemeId::Gc(3), 4, 6),
        (SchemeId::GcHet(3, 1), 4, 6),
        (SchemeId::Pc, 4, 8),
        (SchemeId::Pcmm, 4, 8),
        (SchemeId::Lb, 4, 6),
    ];
    for &(id, r, k) in cases {
        for ingest in [0.0, 0.15] {
            let mut point = EvalPoint::new(n, r, k, trials, seed)
                .with_schemes(&[id])
                .with_ingest(ingest);
            point.threads = 1; // the policy arm is single-stream (shard 0)
            let want = evaluate(&point, &model).remove(0);
            let got = run_policy_rounds(
                &PolicyRunConfig {
                    scheme: id,
                    policy: PolicyKind::Static,
                    n,
                    r,
                    k,
                    rounds: trials,
                    ingest_ms: ingest,
                    seed,
                    // S = 1 MUST dispatch to the synchronous loop —
                    // this whole test is the bit-identity pin
                    staleness: 1,
                },
                &PerRound(&model),
                None,
                None,
            )
            .unwrap();
            assert_eq!(got.replans, 0, "{id} static never replans");
            let e = &got.estimate;
            assert_eq!(e.mean.to_bits(), want.mean.to_bits(), "{id} ingest {ingest} mean");
            assert_eq!(e.p50.to_bits(), want.p50.to_bits(), "{id} ingest {ingest} p50");
            assert_eq!(e.p95.to_bits(), want.p95.to_bits(), "{id} ingest {ingest} p95");
            assert_eq!(e.min.to_bits(), want.min.to_bits(), "{id} ingest {ingest} min");
            assert_eq!(e.max.to_bits(), want.max.to_bits(), "{id} ingest {ingest} max");
        }
    }
}

#[test]
fn prepared_evaluators_are_reusable_and_deterministic() {
    // prepare once, evaluate the same point twice → identical results
    // (evaluator state must reset per round, not leak across rounds)
    let model = TruncatedGaussianModel::scenario1(6);
    let point = EvalPoint::new(6, 3, 6, 800, 21)
        .with_schemes(&[SchemeId::Cs, SchemeId::Gc(3), SchemeId::Pc, SchemeId::Lb]);
    let a = evaluate(&point, &model);
    let b = evaluate(&point, &model);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.mean.to_bits(), y.mean.to_bits(), "{}", x.scheme);
        assert_eq!(x.p95.to_bits(), y.p95.to_bits(), "{}", x.scheme);
    }
}
