//! Telemetry subsystem integration tests: the registry's
//! zero-steady-state-allocation contract, the reactor-served scrape
//! listener's HTTP robustness over real sockets, and offline span
//! reconstruction from a recorded fleet trace.
//!
//! Allocation counting uses a wrapping [`GlobalAlloc`] with a
//! **thread-local** counter — the test binary runs its cases on
//! parallel threads, so a process-global counter would let one test's
//! warm-up pollute another's steady-state window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::io::{Read as _, Write as _};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use straggler_sched::telemetry::{
    encode_prometheus_into, metrics as tm, snapshot_into, spans_from_trace, FlightRecorder,
    MetricsServer, Snapshot,
};
use straggler_sched::trace::TraceStore;

// ---------------------------------------------------------------------------
// counting allocator
// ---------------------------------------------------------------------------

struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

#[inline]
fn note_alloc() {
    // `try_with`: the allocator may be entered during TLS teardown,
    // where `with` would abort
    let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        note_alloc();
        System.alloc(l)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        note_alloc();
        System.alloc_zeroed(l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new: usize) -> *mut u8 {
        note_alloc();
        System.realloc(p, l, new)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static COUNTING: CountingAlloc = CountingAlloc;

fn allocs_here() -> u64 {
    ALLOCS.try_with(Cell::get).unwrap_or(0)
}

// ---------------------------------------------------------------------------
// zero-allocation contract
// ---------------------------------------------------------------------------

/// Past warm-up (histogram state built, quantile estimator degraded to
/// the fixed grid, snapshot/encode buffers grown) none of the hot
/// registry paths may touch the allocator.
#[test]
fn registry_hot_paths_do_not_allocate_when_warm() {
    // warm-up: push the histogram past the exact-mode cap (4096) so the
    // estimator sits on the alloc-free grid, then grow the reusable
    // snapshot + exposition buffers once
    for i in 0..6000 {
        tm::MASTER_DWELL_US.record((i % 1013) as f64);
    }
    let mut snap = Snapshot::default();
    let mut body = String::new();
    snapshot_into(&mut snap);
    encode_prometheus_into(&mut body, &snap);
    snapshot_into(&mut snap);
    encode_prometheus_into(&mut body, &snap);

    let before = allocs_here();
    for i in 0..10_000u64 {
        tm::MASTER_FRAMES_TOTAL.inc();
        tm::WORKER_COMPUTE_US_TOTAL.add(17);
        tm::RING_ROUNDS_IN_FLIGHT.set(i as f64);
        tm::MASTER_DWELL_US.record((i % 997) as f64);
    }
    assert_eq!(
        allocs_here() - before,
        0,
        "counter inc / gauge set / warm histogram record must not allocate"
    );

    let before = allocs_here();
    snapshot_into(&mut snap);
    encode_prometheus_into(&mut body, &snap);
    assert_eq!(
        allocs_here() - before,
        0,
        "warm snapshot_into + Prometheus encode must reuse their buffers"
    );
    assert!(body.contains("straggler_master_frames_total"));
}

// ---------------------------------------------------------------------------
// scrape listener over real sockets
// ---------------------------------------------------------------------------

/// Run one blocking HTTP exchange against `srv`, pumping the server's
/// poll loop from this thread until the client thread finishes (the
/// listener is single-threaded by design — it only makes progress when
/// pumped, exactly like when it rides the master's reactor).  The read
/// side is tolerant: the server hard-closes after each response, so a
/// late RST must lose the response bytes, never panic the client.
fn exchange(srv: &mut MetricsServer, request: Vec<u8>) -> String {
    let addr = srv.addr();
    let client = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).expect("connect to scrape listener");
        s.write_all(&request).expect("send request");
        s.set_read_timeout(Some(Duration::from_secs(5)))
            .expect("read timeout");
        let mut resp = Vec::new();
        let mut buf = [0u8; 4096];
        loop {
            match s.read(&mut buf) {
                Ok(0) => break,
                Ok(k) => resp.extend_from_slice(&buf[..k]),
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break, // reset/timeout — keep whatever arrived
            }
        }
        String::from_utf8_lossy(&resp).into_owned()
    });
    let deadline = Instant::now() + Duration::from_secs(20);
    while !client.is_finished() {
        assert!(Instant::now() < deadline, "scrape exchange stalled");
        srv.pump(10);
    }
    client.join().expect("scrape client panicked")
}

#[test]
fn scrape_server_serves_metrics_and_survives_malformed_requests() {
    tm::MASTER_ROUNDS_TOTAL.inc(); // ensure a non-trivial exposition
    let mut srv = MetricsServer::bind("127.0.0.1:0").expect("bind scrape listener");

    // happy path: full exposition with the v0.0.4 content type
    let ok = exchange(&mut srv, b"GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n".to_vec());
    assert!(ok.starts_with("HTTP/1.1 200 OK"), "got: {ok}");
    assert!(ok.contains("Content-Type: text/plain; version=0.0.4"));
    assert!(ok.contains("# TYPE straggler_master_rounds_total counter"));
    assert!(ok.contains("straggler_master_rounds_total"));

    // "/" is an alias for the scrape path
    let root = exchange(&mut srv, b"GET / HTTP/1.0\r\n\r\n".to_vec());
    assert!(root.starts_with("HTTP/1.1 200 OK"), "got: {root}");

    // wrong path / method / garbage are answered, never crash the pump
    let nf = exchange(&mut srv, b"GET /nope HTTP/1.1\r\n\r\n".to_vec());
    assert!(nf.starts_with("HTTP/1.1 404 Not Found"), "got: {nf}");
    let bm = exchange(&mut srv, b"POST /metrics HTTP/1.1\r\n\r\n".to_vec());
    assert!(bm.starts_with("HTTP/1.1 405 Method Not Allowed"), "got: {bm}");
    let mal = exchange(&mut srv, b"garbage\r\n\r\n".to_vec());
    assert!(mal.starts_with("HTTP/1.1 400 Bad Request"), "got: {mal}");

    // an oversized request (no terminator) is cut off with 400 rather
    // than buffered forever; the close-with-unread-bytes race means the
    // client may see a reset instead of the status line, so the hard
    // assertion is on the server's own error accounting
    let errors_before = tm::TELEMETRY_SCRAPE_ERRORS_TOTAL.get();
    let huge = exchange(&mut srv, vec![b'A'; 9 * 1024]);
    if !huge.is_empty() {
        assert!(huge.starts_with("HTTP/1.1 400 Bad Request"), "got: {huge}");
    }
    assert!(
        tm::TELEMETRY_SCRAPE_ERRORS_TOTAL.get() > errors_before,
        "oversized request must be rejected server-side"
    );

    // a peer that connects and hangs up without a request is dropped
    // silently and the next scrape still works
    drop(TcpStream::connect(srv.addr()).expect("connect-and-abandon"));
    for _ in 0..5 {
        srv.pump(10);
    }
    let again = exchange(&mut srv, b"GET /metrics HTTP/1.1\r\n\r\n".to_vec());
    assert!(again.starts_with("HTTP/1.1 200 OK"), "got: {again}");
}

/// The three JSON endpoints riding the same listener: `/healthz`,
/// `/catalog`, and the flight-recorder dump at `/debug/flight` (empty
/// shape without an attached recorder, real ring contents with one).
#[test]
fn scrape_server_serves_health_catalog_and_flight() {
    use std::cell::RefCell;
    use std::rc::Rc;

    let mut srv = MetricsServer::bind("127.0.0.1:0").expect("bind scrape listener");

    let hz = exchange(&mut srv, b"GET /healthz HTTP/1.1\r\n\r\n".to_vec());
    assert!(hz.starts_with("HTTP/1.1 200 OK"), "got: {hz}");
    assert!(hz.contains("Content-Type: application/json"));
    assert!(hz.contains("\"status\":\"ok\""), "got: {hz}");
    assert!(hz.contains("\"uptime_us\""), "got: {hz}");
    assert!(hz.contains("\"rounds_applied\""), "got: {hz}");

    let cat = exchange(&mut srv, b"GET /catalog HTTP/1.1\r\n\r\n".to_vec());
    assert!(cat.starts_with("HTTP/1.1 200 OK"), "got: {cat}");
    assert!(cat.contains("Content-Type: application/json"));
    // the catalog must list every registered series, new phase
    // histograms and anomaly counter included
    for name in [
        "straggler_master_rounds_total",
        "straggler_phase_compute_ms",
        "straggler_phase_queue_ms",
        "straggler_phase_network_ms",
        "straggler_phase_dwell_ms",
        "straggler_anomaly_total",
        "straggler_clock_offset_us",
    ] {
        assert!(cat.contains(name), "catalog missing {name}: {cat}");
    }

    // no recorder attached: an empty, well-shaped dump
    let empty = exchange(&mut srv, b"GET /debug/flight HTTP/1.1\r\n\r\n".to_vec());
    assert!(empty.starts_with("HTTP/1.1 200 OK"), "got: {empty}");
    assert!(empty.contains("\"events\":[]"), "got: {empty}");

    // attach a ring with one phase and one anomaly event; the dump
    // reflects the shared state on the next request
    let flight = Rc::new(RefCell::new(FlightRecorder::new(8)));
    flight
        .borrow_mut()
        .record(1_000, "phase", 3, 1, [2.0, 0.1, 0.5, 0.05]);
    flight
        .borrow_mut()
        .record(2_000, "anomaly", 3, 1, [0.0, 16.0, 2.0, 4.0]);
    srv.set_flight(flight.clone());
    let dump = exchange(&mut srv, b"GET /debug/flight HTTP/1.1\r\n\r\n".to_vec());
    assert!(dump.starts_with("HTTP/1.1 200 OK"), "got: {dump}");
    assert!(dump.contains("\"recorded\":2"), "got: {dump}");
    assert!(dump.contains("\"kind\":\"phase\""), "got: {dump}");
    assert!(dump.contains("\"kind\":\"anomaly\""), "got: {dump}");
}

// ---------------------------------------------------------------------------
// offline span reconstruction
// ---------------------------------------------------------------------------

/// `straggler trace report` path: reconstruct critical-path spans from
/// the committed fleet fixture and sanity-check the attribution.
#[test]
fn spans_from_trace_reconstructs_fleet_fixture() {
    let store = TraceStore::load(std::path::Path::new("tests/fixtures/fleet_trace.jsonl"))
        .expect("load fleet fixture");
    let n = store.n_workers();
    assert_eq!(n, 8, "fixture fleet size");
    let spans = spans_from_trace(&store, n).expect("span reconstruction");

    assert!(spans.rounds > 0, "fixture must yield rounds");
    assert_eq!(spans.completion.count, spans.rounds);
    assert_eq!(spans.attribution.len(), n);
    assert!(
        spans.completion.mean_ms > 0.0 && spans.completion.mean_ms.is_finite(),
        "completion mean: {}",
        spans.completion.mean_ms
    );
    // completion decomposes: wait-first never exceeds the full span
    assert!(spans.wait_first.mean_ms <= spans.completion.mean_ms + 1e-9);
    // every round's k-th distinct delivery is attributed to exactly one
    // worker, so attribution sums back to the round count
    let critical: u64 = spans.attribution.iter().map(|a| a.critical_rounds).sum();
    assert_eq!(critical, spans.rounds);
    // every worker shipped frames in the fixture
    assert!(spans.attribution.iter().all(|a| a.frames > 0));
    // decode has no offline counterpart
    assert_eq!(spans.decode.count, 0);

    // the k threshold is honored: a looser target completes no later
    let loose = spans_from_trace(&store, 1).expect("k = 1 reconstruction");
    assert!(loose.completion.mean_ms <= spans.completion.mean_ms + 1e-9);
    assert!(
        loose.wasted.post_completion_frames >= spans.wasted.post_completion_frames,
        "earlier completion strictly grows post-completion waste"
    );
}
