//! Trace subsystem tests: codec round-trip bit-identity (randomized),
//! fit parameter recovery on synthetic data, and the committed-fixture
//! record → fit → replay loop with its pinned-seed determinism digest.

use straggler_sched::adaptive::{run_policy_rounds, PerRound, PolicyKind, PolicyRunConfig};
use straggler_sched::delay::exponential::ShiftedExp;
use straggler_sched::delay::TruncatedGaussian;
use straggler_sched::scheme::SchemeId;
use straggler_sched::trace::{
    fit_traces, replay, FitFamily, ReplayConfig, ReplaySource, TraceEvent, TraceRecorder,
    TraceStore,
};
use straggler_sched::util::json::Json;
use straggler_sched::util::rng::Rng;

const FIXTURE: &str = "tests/fixtures/fleet_trace.jsonl";
const GOLDEN: &str = "tests/fixtures/fleet_trace.golden.json";

/// Run `prop` over `cases` seeded cases; panic with the failing seed
/// (same in-tree property harness as `tests/proptests.rs`).
fn forall(name: &str, cases: u64, mut prop: impl FnMut(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::seed_from_u64(0x7124CE ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            eprintln!("property {name} FAILED at case seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

fn random_store(rng: &mut Rng) -> TraceStore {
    let schemes = ["CS", "GC(2)", "GCH(4,1)", "PCMM", "cyclic/g2", "ünïcode✓"];
    let n_events = 1 + rng.below(60);
    let events: Vec<TraceEvent> = (0..n_events)
        .map(|_| {
            let round = rng.below(1000) as u32;
            TraceEvent {
                worker: rng.below(16) as u32,
                round,
                slot: rng.below(32) as u32,
                tasks: 1 + rng.below(8) as u32,
                // mix exact integers (serialize without a decimal point),
                // zeros, and arbitrary positive reals
                compute_s: match rng.below(4) {
                    0 => 0.0,
                    1 => rng.below(10) as f64,
                    _ => rng.f64() * 1e-2,
                },
                comm_s: rng.f64() * 1e-2,
                // worker-queue delay (binary v3): zeros (legacy shape)
                // and small positive reals both round-trip
                queue_s: if rng.below(3) == 0 { 0.0 } else { rng.f64() * 1e-3 },
                bytes: rng.below(1 << 20) as u64,
                scheme: schemes[rng.below(schemes.len())].to_string(),
                replanned: rng.below(2) == 1,
                // θ-version tag (protocol v4): sync (= round) and stale
                // (< round, gap ≤ 7) tags, never ahead of the round
                version: round.saturating_sub(rng.below(8) as u32),
            }
        })
        .collect();
    TraceStore::new(events).expect("valid random events")
}

#[test]
fn prop_jsonl_roundtrip_bit_identity() {
    forall("jsonl round-trip", 150, |rng| {
        let store = random_store(rng);
        let back = TraceStore::from_jsonl(&store.to_jsonl()).expect("reparse");
        assert_eq!(back.len(), store.len());
        for (a, b) in back.events().iter().zip(store.events()) {
            assert_eq!(a.compute_s.to_bits(), b.compute_s.to_bits());
            assert_eq!(a.comm_s.to_bits(), b.comm_s.to_bits());
            assert_eq!(a, b);
        }
    });
}

#[test]
fn prop_binary_roundtrip_bit_identity() {
    forall("binary round-trip", 150, |rng| {
        let store = random_store(rng);
        let back = TraceStore::from_binary(&store.to_binary()).expect("reparse");
        assert_eq!(back, store);
        // and the two codecs agree with each other
        let via_jsonl = TraceStore::from_jsonl(&store.to_jsonl()).unwrap();
        assert_eq!(via_jsonl, back);
    });
}

#[test]
fn fit_recovers_shifted_exp_parameters() {
    let truth_comp = ShiftedExp::new(0.15, 5.0);
    let truth_comm = ShiftedExp::new(0.4, 2.0);
    let mut rng = Rng::seed_from_u64(41);
    let mut rec = TraceRecorder::new("CS");
    for round in 0..1500 {
        rec.push_slot(
            round,
            0,
            0,
            truth_comp.sample(&mut rng),
            truth_comm.sample(&mut rng),
            false,
            round as u32,
        );
    }
    let fit = fit_traces(&rec.into_store()).unwrap();
    let comp = &fit.workers[0].comp;
    assert!((comp.exp.dist.shift - 0.15).abs() < 0.02, "shift {}", comp.exp.dist.shift);
    assert!((comp.exp.dist.rate - 5.0).abs() / 5.0 < 0.1, "rate {}", comp.exp.dist.rate);
    assert!(comp.exp.ks < 0.05, "comp ks {}", comp.exp.ks);
    assert_eq!(comp.best(), FitFamily::ShiftedExp);
    let comm = &fit.workers[0].comm;
    assert!((comm.exp.dist.shift - 0.4).abs() < 0.03, "shift {}", comm.exp.dist.shift);
    assert!((comm.exp.dist.rate - 2.0).abs() / 2.0 < 0.1, "rate {}", comm.exp.dist.rate);
}

#[test]
fn fit_recovers_truncated_gaussian_shape() {
    let truth = TruncatedGaussian::symmetric(0.5, 0.2, 0.2);
    let mut rng = Rng::seed_from_u64(42);
    let mut rec = TraceRecorder::new("CS");
    for round in 0..1500 {
        rec.push_slot(round, 0, 0, truth.sample(&mut rng), truth.sample(&mut rng), false, round as u32);
    }
    let fit = fit_traces(&rec.into_store()).unwrap();
    let comp = &fit.workers[0].comp;
    // the moment fit recovers the mean exactly; its σ is the *sample*
    // std of the truncated law (≈ 0.54 σ under ±1σ truncation), and KS
    // still picks the right family by a wide margin
    assert!((comp.tg.dist.mu - 0.5).abs() < 0.01, "mu {}", comp.tg.dist.mu);
    assert!(comp.tg.ks < 0.1, "tg ks {}", comp.tg.ks);
    assert!(comp.tg.ks < comp.exp.ks, "tg {} vs exp {}", comp.tg.ks, comp.exp.ks);
    assert_eq!(comp.best(), FitFamily::TruncatedGaussian);
}

#[test]
fn fixture_fit_finds_the_two_tiers() {
    let store = TraceStore::load(std::path::Path::new(FIXTURE)).expect("committed fixture");
    assert_eq!(store.n_workers(), 8);
    assert_eq!(store.rounds(), 40);
    assert_eq!(store.schemes(), vec!["GC(2)".to_string()]);
    let fit = fit_traces(&store).unwrap();
    assert_eq!(fit.fast_workers(), vec![0, 1, 2, 3]);
    assert_eq!(fit.slow_workers(), vec![4, 5, 6, 7]);
    let (fast, slow) = (fit.tier_mean_ms(0).unwrap(), fit.tier_mean_ms(1).unwrap());
    assert!(slow / fast > 2.0, "tier ratio {fast} vs {slow}");
    // the fixture carries 5 % transient straggle rounds neither
    // parametric family models (that misfit is WHY empirical replay is
    // the default) — KS honestly reports it, so the bound is loose
    for w in &fit.workers {
        assert!(w.comp.best_ks() < 0.3, "worker {} comp ks {}", w.worker, w.comp.best_ks());
        assert!(w.comm.best_ks() < 0.3, "worker {} comm ks {}", w.worker, w.comm.best_ks());
    }
}

fn fixture_replay_config() -> ReplayConfig {
    ReplayConfig::matrix(8, 400, 0xD1617A1)
}

/// The acceptance loop: committed fixture → replay runs every
/// registered scheme family and the static/order/load policies, with a
/// pinned-seed determinism digest.  The digest is additionally checked
/// against (or, on first toolchain run, written to) a golden file so
/// cross-version drift in the engine surfaces here.
#[test]
fn fixture_replay_matrix_is_deterministic() {
    let store = TraceStore::load(std::path::Path::new(FIXTURE)).expect("committed fixture");
    let cfg = fixture_replay_config();
    let a = replay(&store, &cfg).unwrap();
    let b = replay(&store, &cfg).unwrap();
    assert_eq!(a.digest, b.digest, "same trace + config ⇒ same digest");
    for (x, y) in a.cells.iter().zip(&b.cells) {
        assert_eq!(
            x.estimate.mean.to_bits(),
            y.estimate.mean.to_bits(),
            "{} × {}",
            x.scheme,
            x.policy
        );
    }
    // every registered scheme family runs under the static policy
    for want in [
        SchemeId::Cs,
        SchemeId::Ss,
        SchemeId::Ra,
        SchemeId::Gc(2),
        SchemeId::GcHet(2, 1),
        SchemeId::Pc,
        SchemeId::Pcmm,
        SchemeId::Lb,
    ] {
        assert!(
            a.cells
                .iter()
                .any(|c| c.scheme == want && c.policy == PolicyKind::Static),
            "static cell missing for {want}"
        );
    }
    // and the order/load policies run on the re-plannable bases
    for policy in [PolicyKind::AdaptiveOrder, PolicyKind::AdaptiveLoad] {
        for base in [SchemeId::Cs, SchemeId::Ss, SchemeId::Gc(2)] {
            assert!(
                a.cells.iter().any(|c| c.scheme == base && c.policy == policy),
                "{policy} cell missing for {base}"
            );
        }
    }
    // a different seed must change the digest (the pin is not vacuous)
    let other = replay(
        &store,
        &ReplayConfig {
            seed: 0xD1617A2,
            ..cfg
        },
    )
    .unwrap();
    assert_ne!(a.digest, other.digest);

    // golden pin: verify against the committed digest when present.
    // The authoring environment cannot generate it (no toolchain), so
    // when it is absent the pin is inactive — set TRACE_GOLDEN_WRITE=1
    // on a toolchain machine to emit it, then commit the file; a plain
    // test run never mutates the source tree.
    let digest_hex = format!("{:016x}", a.digest);
    let golden_path = std::path::Path::new(GOLDEN);
    if let Ok(text) = std::fs::read_to_string(golden_path) {
        let v = Json::parse(&text).expect("golden file is JSON");
        let want = v.get("digest").and_then(Json::as_str).expect("golden digest");
        assert_eq!(
            digest_hex, want,
            "fixture replay digest drifted from the committed golden — if the \
             engine change is intentional, regenerate {GOLDEN} with \
             TRACE_GOLDEN_WRITE=1"
        );
    } else if std::env::var_os("TRACE_GOLDEN_WRITE").is_some() {
        let body = Json::obj(vec![
            ("fixture", Json::Str(FIXTURE.into())),
            ("trials", Json::Num(400.0)),
            ("seed", Json::Str(format!("{:#x}", 0xD1617A1u64))),
            ("digest", Json::Str(digest_hex)),
        ])
        .to_string_pretty();
        std::fs::write(golden_path, body).expect("write golden");
        eprintln!("wrote {GOLDEN} — commit it to pin the fixture replay digest");
    } else {
        eprintln!(
            "note: {GOLDEN} absent — digest {digest_hex} unpinned \
             (generate with TRACE_GOLDEN_WRITE=1 and commit)"
        );
    }
}

/// The decode-weight cache must actually pay off on the committed
/// fleet: the fixture's two-tier straggler pattern makes responder
/// subsets repeat, so replay's decode-cache leg reports a nonzero hit
/// rate for both coded schemes — and the leg itself is deterministic.
#[test]
fn fixture_replay_reports_decode_cache_hits() {
    let store = TraceStore::load(std::path::Path::new(FIXTURE)).expect("committed fixture");
    let cfg = fixture_replay_config();
    let out = replay(&store, &cfg).unwrap();
    let schemes: Vec<_> = out.decode_cache.iter().map(|d| d.scheme).collect();
    assert_eq!(schemes, vec![SchemeId::Pc, SchemeId::Pcmm]);
    for d in &out.decode_cache {
        assert_eq!(d.rounds, 400);
        assert_eq!(d.stats.lookups(), 400, "{}: one decode per round", d.scheme);
        assert!(
            d.stats.hits > 0,
            "{}: the two-tier fleet's responder subsets must repeat",
            d.scheme
        );
    }
    // PC at r = n collapses to threshold 1: at most n distinct
    // single-responder subsets exist, so misses are bounded by the
    // fleet size and the hit rate is near 1
    let pc = &out.decode_cache[0];
    assert!(pc.stats.misses <= 8, "PC misses {}", pc.stats.misses);
    assert!(pc.stats.hit_rate() > 0.9, "PC hit rate {}", pc.stats.hit_rate());
    let again = replay(&store, &cfg).unwrap();
    for (x, y) in out.decode_cache.iter().zip(&again.decode_cache) {
        assert_eq!(x.stats, y.stats, "{}: decode-cache leg must be deterministic", x.scheme);
    }
}

#[test]
fn recording_does_not_perturb_the_run() {
    // the trace tap must be an observer: a recorded run's estimate is
    // bit-identical to an unrecorded one
    let model = straggler_sched::adaptive::two_tier_model(6, 2, 3.0);
    let cfg = PolicyRunConfig {
        scheme: SchemeId::Gc(2),
        policy: PolicyKind::AdaptiveOrder,
        n: 6,
        r: 4,
        k: 6,
        rounds: 120,
        ingest_ms: 0.05,
        seed: 77,
        staleness: 1,
    };
    let plain = run_policy_rounds(&cfg, &PerRound(&model), None, None).unwrap();
    let mut rec = TraceRecorder::with_fleet("GC(2)", 6);
    let recorded = run_policy_rounds(&cfg, &PerRound(&model), None, Some(&mut rec)).unwrap();
    assert_eq!(plain.estimate.mean.to_bits(), recorded.estimate.mean.to_bits());
    assert_eq!(plain.decision_digest, recorded.decision_digest);
    assert!(!rec.is_empty(), "the tap saw the run");
    let store = rec.into_store();
    assert_eq!(store.n_workers(), 6, "declared fleet");
    assert_eq!(store.rounds(), 120);
    // censoring: a round delivers at most n·r slots
    assert!(store.len() <= 120 * 6 * 4);
    // replanned rounds are flagged (the order policy replans at least once)
    assert!(store.events().iter().any(|e| e.replanned));
}

#[test]
fn recorded_sim_trace_closes_the_loop() {
    // record → fit → replay without touching disk: the simulated trace
    // of a two-tier fleet fits back into two tiers and replays
    let model = straggler_sched::adaptive::two_tier_model(6, 3, 4.0);
    let cfg = PolicyRunConfig {
        scheme: SchemeId::Cs,
        policy: PolicyKind::Static,
        n: 6,
        r: 6,
        k: 6,
        rounds: 250,
        ingest_ms: 0.0,
        seed: 3,
        staleness: 1,
    };
    let mut rec = TraceRecorder::with_fleet("CS", 6);
    run_policy_rounds(&cfg, &PerRound(&model), None, Some(&mut rec)).unwrap();
    let store = rec.into_store();
    let fit = fit_traces(&store).unwrap();
    // two_tier_model makes workers 0..3 slow (4×)
    assert_eq!(fit.slow_workers(), vec![0, 1, 2], "{:?}", fit.tier_of);
    let out = replay(
        &store,
        &ReplayConfig {
            schemes: vec![SchemeId::Cs, SchemeId::Gc(2), SchemeId::Lb],
            policies: vec![PolicyKind::Static, PolicyKind::LoadRate],
            source: ReplaySource::Empirical,
            ..ReplayConfig::matrix(6, 150, 9)
        },
    )
    .unwrap();
    // LB lower-bounds the per-task-streaming schemes on the same
    // stream (pointwise, eq. 46).  Grouped schemes are exempt: a flush
    // can deliver several tasks on one early arrival, which the §V
    // genie bound does not dominate (EXPERIMENTS.md §Schemes).
    let lb = out
        .cells
        .iter()
        .find(|c| c.scheme == SchemeId::Lb)
        .unwrap()
        .estimate
        .mean;
    for cell in out.cells.iter().filter(|c| c.scheme == SchemeId::Cs) {
        assert!(
            cell.estimate.mean >= lb - 1e-9,
            "{} × {} beat the genie bound",
            cell.scheme,
            cell.policy
        );
    }
    // load-rate runs on the GC base (and is skipped nowhere here)
    assert!(out
        .cells
        .iter()
        .any(|c| c.scheme == SchemeId::Gc(2) && c.policy == PolicyKind::LoadRate));
}

#[test]
fn fixture_survives_binary_conversion() {
    let store = TraceStore::load(std::path::Path::new(FIXTURE)).unwrap();
    let back = TraceStore::from_binary(&store.to_binary()).unwrap();
    assert_eq!(back, store);
    // windowing drops warmup rounds without touching the rest
    let tail = store.window(10, 40);
    assert_eq!(tail.rounds(), 40);
    assert!(tail.len() < store.len());
    assert!(tail.events().iter().all(|e| e.round >= 10));
}
