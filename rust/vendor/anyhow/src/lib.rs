//! Minimal, dependency-free shim for the subset of the `anyhow` API this
//! workspace uses.  The build is fully offline (DESIGN.md §5), so instead
//! of pulling the real crate from a registry we vendor a compatible
//! `Error`/`Result`/`Context` + `anyhow!`/`bail!`/`ensure!` surface.
//!
//! Semantics mirror `anyhow` where the workspace relies on them:
//!
//! * `Display` prints the **outermost** message only;
//! * alternate `Display` (`{:#}`) prints the whole context chain joined
//!   with `": "` (what `src/main.rs` uses for fatal errors);
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`,
//!   capturing its source chain;
//! * `Context` adds a message layer to `Result` and turns `Option` into
//!   `Result`.

use std::error::Error as StdError;
use std::fmt;

/// Error type: an outermost message plus the chain of underlying causes
/// (most recent context first).
pub struct Error {
    /// `chain[0]` is the outermost message; later entries are causes.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self {
            chain: vec![message.to_string()],
        }
    }

    /// Prepend a context message (the `anyhow::Context` mechanism).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause messages, outermost first.
    pub fn chain_messages(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Self { chain }
    }
}

/// `anyhow::Result` with the usual defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] when a condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_shows_outermost_message_only() {
        let e: Error = Result::<(), _>::Err(io_err())
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(e.to_string(), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: no such file");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<i32> {
            let n: i32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(f().unwrap(), 12);

        fn g() -> Result<i32> {
            let n: i32 = "nope".parse()?;
            Ok(n)
        }
        assert!(g().unwrap_err().to_string().contains("invalid digit"));
    }

    #[test]
    fn option_context_and_macros() {
        let e = None::<i32>.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");

        fn h(flag: bool) -> Result<()> {
            ensure!(flag, "flag was {flag}");
            bail!("unreachable {}", 1 + 1)
        }
        assert_eq!(h(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(h(true).unwrap_err().to_string(), "unreachable 2");

        let e = anyhow!("x = {}", 7);
        assert_eq!(e.to_string(), "x = 7");
    }

    #[test]
    fn with_context_is_lazy_and_layered() {
        let e: Error = Result::<(), _>::Err(io_err())
            .with_context(|| format!("step {}", 3))
            .unwrap_err();
        assert_eq!(e.to_string(), "step 3");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
        assert!(dbg.contains("no such file"), "{dbg}");
    }
}
