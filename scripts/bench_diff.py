#!/usr/bin/env python3
"""Diff a fresh hot-path bench report against the committed baseline.

Usage:
    python3 scripts/bench_diff.py BASELINE.json CURRENT.json

Both files use the `util::benchkit::write_json_report` schema
(`{"target": ..., "benchmarks": [{name, mean_ns, ...}, ...]}`).

Rules:

* If the baseline is a placeholder (`"placeholder": true` or an empty
  benchmark list — the authoring environment has no toolchain, so the
  first measured report comes from CI or a dev machine), the diff is
  skipped gracefully: there is nothing honest to compare against.
* Benchmarks are grouped by their `name` prefix before the first `/`
  (`aggregate/...`, `decode/...`, `fleet/...`, ...).  For every watched
  group, the geometric-mean ratio of matched benchmarks' `mean_ns` is
  computed; a group whose geomean regresses more than the threshold
  fails the run (exit 1).  The geomean keeps one noisy micro-bench from
  flaking the gate while still catching real regressions.
* Benchmarks new in the current run are reported but never fail; a
  baseline benchmark missing from the current run is a warning.
"""

import json
import math
import sys

# fail a watched group whose geomean mean_ns grows beyond +25 %
THRESHOLD = 1.25
# the perf surfaces EXPERIMENTS.md §Perf tracks; other groups are
# reported informationally only
WATCHED = (
    "aggregate",
    "ring",
    "decode",
    "fleet",
    "batch",
    "coupled3",
    "estimator",
    "scheme",
    "net",
    "telemetry",
)


def load(path):
    with open(path) as f:
        return json.load(f)


def group_of(name):
    return name.split("/", 1)[0]


def main(argv):
    if len(argv) != 3:
        print(__doc__.strip().splitlines()[2].strip(), file=sys.stderr)
        return 2
    baseline_path, current_path = argv[1], argv[2]
    base = load(baseline_path)
    if base.get("placeholder") or not base.get("benchmarks"):
        print(
            f"bench-diff: baseline {baseline_path} is a placeholder with no "
            "measured numbers — skipping comparison (commit a measured "
            "report to arm the gate)"
        )
        return 0
    cur = load(current_path)
    base_by = {b["name"]: b for b in base["benchmarks"]}
    cur_names = set()
    ratios = {}
    for b in cur.get("benchmarks", []):
        cur_names.add(b["name"])
        ref = base_by.get(b["name"])
        if ref is None:
            print(f"  new benchmark (no baseline yet): {b['name']}")
            continue
        ratios.setdefault(group_of(b["name"]), []).append(
            (b["name"], b["mean_ns"] / ref["mean_ns"])
        )
    for name in sorted(set(base_by) - cur_names):
        print(f"  warning: baseline benchmark missing from current run: {name}")

    failed = []
    for grp in sorted(ratios):
        pairs = ratios[grp]
        geo = math.exp(sum(math.log(r) for _, r in pairs) / len(pairs))
        worst_name, worst = max(pairs, key=lambda p: p[1])
        watched = grp in WATCHED
        status = "ok"
        if watched and geo > THRESHOLD:
            failed.append(grp)
            status = "REGRESSED"
        elif not watched:
            status = "info"
        print(
            f"  {grp:<12} geomean {geo - 1.0:+7.1%}  "
            f"(worst: {worst_name} {worst - 1.0:+.1%})  [{status}]"
        )
    if failed:
        print(
            f"bench-diff: FAIL — group(s) {', '.join(failed)} regressed "
            f"beyond +{THRESHOLD - 1.0:.0%} geomean vs {baseline_path}"
        )
        return 1
    print("bench-diff: no watched group regressed beyond the threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
